//! Minimal JSON parser + writer (serde is not in the offline vendor set).
//!
//! Parses the `artifacts/manifest.json` emitted by `python/compile/aot.py`
//! and serializes run reports / metric dumps. Supports the full JSON value
//! grammar except `\u` surrogate pairs outside the BMP (not produced by any
//! of our writers).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors (used by manifest.rs; fail loudly with context) --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => anyhow::bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            anyhow::bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => anyhow::bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => anyhow::bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => anyhow::bail!("expected object, got {other:?}"),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = s.chars().next().unwrap();
                    self.pos = start + ch.len_utf8();
                    let _ = c;
                    out.push(ch);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

// -- writer ---------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Convenience builders for report writers.
impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\\nthere\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c", "d": null}], "e": -0.25}"#).unwrap();
        assert_eq!(v.get("e").unwrap().as_f64().unwrap(), -0.25);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café é");
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
