//! Deterministic PRNG + distributions (no external crates are available in
//! the offline build, so this is our substrate for all stochastic pieces:
//! client sampling, device heterogeneity, Dirichlet partitioning, synthetic
//! data generation).
//!
//! Core generator is xoshiro256++ seeded through SplitMix64 — the standard
//! construction recommended by the xoshiro authors; passes BigCrush.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so low-entropy seeds (0, 1, 2...) still produce
    /// well-mixed states.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (for per-client / per-module RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (polar-free variant; two uniforms).
    pub fn normal(&mut self) -> f64 {
        // Avoid u == 0 to keep ln() finite.
        let u = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u = u.max(1e-300);
        let v = self.f64();
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }

    /// N(mu, sigma^2).
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma^2)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Gamma(shape, 1) via Marsaglia & Tsang; shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * ones(k)) — the non-iid partitioner of the paper
    /// (CIFAR-10 split with alpha = 0.1).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // Degenerate draw (tiny alpha): put all mass on one class.
            let i = self.usize_below(k);
            g.iter_mut().for_each(|v| *v = 0.0);
            g[i] = 1.0;
            return g;
        }
        g.iter_mut().for_each(|v| *v /= sum);
        g
    }

    /// Sample `m` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_without_replacement(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Categorical draw from (unnormalized, non-negative) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::seed_from(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(2);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::seed_from(3);
        for shape in [0.1, 0.5, 1.0, 3.0, 9.5] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.15 * shape.max(0.5),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seed_from(4);
        for alpha in [0.1, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 10);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn dirichlet_small_alpha_is_peaky() {
        let mut r = Rng::seed_from(5);
        // alpha = 0.05: almost all mass should concentrate on few classes.
        let mut maxes = 0.0;
        let trials = 200;
        for _ in 0..trials {
            let p = r.dirichlet(0.05, 10);
            maxes += p.iter().cloned().fold(0.0, f64::max);
        }
        // E[max] for Dirichlet(0.05, k=10) is ~0.78 (checked vs numpy).
        assert!(maxes / trials as f64 > 0.7);
    }

    #[test]
    fn below_is_in_range_and_unbiased_enough() {
        let mut r = Rng::seed_from(6);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.usize_below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut r = Rng::seed_from(7);
        let s = r.sample_without_replacement(100, 40);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::seed_from(8);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
