//! Fleet edge-clock bench — region-clocked edge aggregators vs the shared
//! (lockstep) clock at fleet_1m scale, across the sampler registry.
//!
//! Two claims being measured:
//!
//! 1. *Clock A/B*: with `hier_clock = region`, a positive flush window and
//!    a priced edge->root uplink, regions hold their partials until the
//!    flush deadline and the root sees them only after the transfer cost
//!    elapses — so the run reports nonzero `edge_uplink_wait_secs` and
//!    STRICTLY fewer root merges than edge flushes (several regions'
//!    flushes batch into one root drain). The shared clock keeps all three
//!    counters at exactly zero (the byte-identity anchor).
//!
//! 2. *Participation dispersion*: per-sampler participation Gini under
//!    both clock modes — whether deferred, batched edge uplinks skew who
//!    gets aggregated compared to lockstep merging, and whether the
//!    availability-aware samplers flatten that skew.
//!
//! Output: an aligned table on stdout plus
//! `results/BENCH_fleet_clocks.json` recording the full grid for
//! EXPERIMENTS.md and CI trending.

use std::time::Instant;

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::config::parse as cfgparse;
use timelyfl::experiment::scenario;
use timelyfl::metrics::report::Table;
use timelyfl::util::json::Json;
use timelyfl::util::stats;

fn main() -> Result<()> {
    benchkit::banner(
        "fleet_clocks",
        "edge-aggregator clocks (region vs shared) x sampler, participation Gini",
    );
    let bench = Bench::new()?;

    // fleet_1m base, downscaled by the bench-scale knob: the fast profile
    // keeps the same markov churn + tree tier at a CI-sized population.
    let mut base = scenario::resolve("fleet_1m")?.config()?;
    if bench.scale.fast {
        base.population = 20_000;
        base.concurrency = 64;
    }
    base.rounds = bench.scale.rounds(4).min(4);
    base.eval_every = base.rounds;

    // A fixed, positive flush window (not `auto`): the A/B claim needs
    // every region's deadline to actually arm, and aligned windows make
    // several regions flush between two aggregation boundaries — the
    // batched-arrival regime where root merges < edge flushes.
    let region_overrides: &[(&str, &str)] = &[
        ("hier_clock", "region"),
        ("hier_flush_secs", "60"),
        ("hier_uplink", "priced"),
        ("hier_up_ratio", "0.25"),
    ];

    let samplers = ["uniform", "stay-prob", "drop-aware"];
    let clocks = ["shared", "region"];

    let mut table = Table::new(&[
        "sampler",
        "clock",
        "particip_gini",
        "mean_particip",
        "edge_flushes",
        "uplink_wait_s",
        "root_merges",
        "wall_secs",
    ]);
    let mut points = Vec::new();

    for sampler in samplers {
        for clock in clocks {
            let mut cfg = base.clone();
            cfgparse::apply_override(&mut cfg, "sampler", sampler)?;
            if clock == "region" {
                for (k, v) in region_overrides {
                    cfgparse::apply_override(&mut cfg, k, v)?;
                }
            }
            cfg.validate()?;
            eprintln!("  {sampler} / {clock} ...");
            let start = Instant::now();
            let report = bench.run(cfg)?;
            let wall = start.elapsed().as_secs_f64();
            let gini = stats::gini(&report.participation);
            let mean_particip = stats::mean(&report.participation);

            if clock == "shared" {
                // The lockstep anchor: no region may hold or price anything.
                anyhow::ensure!(
                    report.edge_flushes == 0
                        && report.edge_uplink_wait_secs == 0.0
                        && report.edge_root_merges == 0,
                    "{sampler}/shared: edge counters must be exactly zero"
                );
            } else {
                // The clocked regime: deadlines fired, the uplink cost the
                // root real simulated time, and arrivals batched.
                anyhow::ensure!(
                    report.edge_flushes > 0,
                    "{sampler}/region: no region ever flushed"
                );
                anyhow::ensure!(
                    report.edge_uplink_wait_secs > 0.0,
                    "{sampler}/region: priced uplink reported zero wait"
                );
                anyhow::ensure!(
                    report.edge_root_merges < report.edge_flushes,
                    "{sampler}/region: expected batched arrivals \
                     (root merges {} !< edge flushes {})",
                    report.edge_root_merges,
                    report.edge_flushes
                );
            }

            table.row(vec![
                sampler.into(),
                clock.into(),
                format!("{gini:.4}"),
                format!("{mean_particip:.3}"),
                report.edge_flushes.to_string(),
                format!("{:.1}", report.edge_uplink_wait_secs),
                report.edge_root_merges.to_string(),
                format!("{wall:.2}"),
            ]);
            points.push(Json::obj(vec![
                ("sampler", Json::str(sampler)),
                ("clock", Json::str(clock)),
                ("participation_gini", Json::num(gini)),
                ("mean_participation", Json::num(mean_particip)),
                ("edge_flushes", Json::num(report.edge_flushes as f64)),
                (
                    "edge_uplink_wait_secs",
                    Json::num(report.edge_uplink_wait_secs),
                ),
                ("edge_root_merges", Json::num(report.edge_root_merges as f64)),
                ("rounds", Json::num(report.total_rounds as f64)),
                ("sim_secs", Json::num(report.sim_secs)),
                ("wall_secs", Json::num(wall)),
            ]));
        }
    }

    let rendered = table.render();
    println!("{rendered}");
    println!(
        "shape target: shared-clock edge counters pinned at zero; region clocks\n\
         show uplink wait > 0 with root merges < edge flushes (batched arrivals);\n\
         availability-aware samplers should not worsen Gini under region clocks."
    );
    let json = Json::obj(vec![
        ("bench", Json::str("fleet_clocks")),
        ("scenario", Json::str("fleet_1m")),
        ("population", Json::num(base.population as f64)),
        ("hier_flush_secs", Json::num(60.0)),
        ("hier_up_ratio", Json::num(0.25)),
        ("points", Json::arr(points)),
    ]);
    benchkit::write_result("BENCH_fleet_clocks.json", &json.to_string());
    benchkit::write_result("fleet_clocks.txt", &rendered);
    Ok(())
}
