//! Availability-aware sampling under correlated regional churn (new
//! scenario, beyond the paper — the PR-5 acceptance table).
//!
//! The `cifar_regional` scenario shards the fleet into regions that fail
//! together (with bandwidth degrading before each outage) and sweeps the
//! sampler axis across every registered strategy. Expected shape: the
//! `stay-prob` policy — which prefers clients whose availability process
//! predicts survival through the sampling horizon — achieves a **higher
//! participation rate and a lower availability-drop share** than the
//! availability-blind `uniform` policy, because it stops handing work to
//! clients that a destabilizing region is about to take down. `drop-aware`
//! (posterior from the observed drop ledger, no process model) should land
//! between them: it learns who churns, but only after paying for the
//! evidence.
//!
//! The same study is one CLI line:
//! `timelyfl sweep --scenario cifar_regional --axis sampler=uniform,stay-prob,drop-aware --seeds 3`.
//!
//! Every cell is replicated over [`SEEDS`] seeds (mean ± std). The
//! avail-share column is the per-seed fraction of sampled/dispatched
//! slots lost to availability churn: `avail_drops / (participations +
//! avail_drops + deadline_drops)`.

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::coordinator::sampler;
use timelyfl::experiment::{scenario, MeanStd, SweepGrid};
use timelyfl::metrics::report::Table;
use timelyfl::metrics::RunReport;

/// Seed replicates per (sampler, strategy) cell.
const SEEDS: usize = 3;

/// Fraction of this run's sampled/dispatched slots lost to churn.
fn avail_share(r: &RunReport) -> f64 {
    let participations: usize = r.rounds.iter().map(|x| x.participants).sum();
    let total = participations + r.total_avail_drops() + r.total_deadline_drops();
    if total == 0 {
        0.0
    } else {
        r.total_avail_drops() as f64 / total as f64
    }
}

fn main() -> Result<()> {
    benchkit::banner(
        "sampler_regional_churn",
        "availability-aware sampling vs uniform under correlated regional churn",
    );
    let bench = Bench::new()?;

    let mut base = scenario::resolve("cifar_regional")?.config()?;
    base.rounds = bench.scale.rounds(40);
    base.eval_every = 20;
    let samplers = sampler::names();
    let grid = SweepGrid::new(base)
        .axis("sampler", &samplers)
        .strategy_axis_all();
    let n_strategies = grid.len() / samplers.len();
    eprintln!(
        "  {} cells ({} samplers x full strategy registry) x {SEEDS} seeds ...",
        grid.len(),
        samplers.len()
    );
    let result = bench.runner().seeds(SEEDS).run(&grid)?;

    let mut t = Table::new(&[
        "sampler",
        "strategy",
        "mean_particip",
        "avail_share",
        "avail_drops",
        "deadline_drops",
        "online_frac",
        "rounds",
    ]);
    let mut csv = String::from(
        "sampler,strategy,seeds,mean_participation,participation_std,avail_share,\
         avail_drops,deadline_drops,online_fraction\n",
    );
    // (sampler, strategy) -> (participation MeanStd, avail-share MeanStd)
    let mut stats: Vec<(String, String, MeanStd, MeanStd)> = Vec::new();

    for (si, sampler_name) in samplers.iter().enumerate() {
        let cells = &result.cells[si * n_strategies..(si + 1) * n_strategies];
        for c in cells {
            let strategy = c.cell.cfg.strategy.clone();
            let s = &c.summary;
            let shares: Vec<f64> = c.reports.iter().map(avail_share).collect();
            let share = MeanStd::of(&shares);
            t.row(vec![
                sampler_name.to_string(),
                strategy.clone(),
                s.mean_participation.fmt(3),
                share.fmt(3),
                s.avail_drops.fmt(1),
                s.deadline_drops.fmt(1),
                s.mean_online_fraction.fmt(3),
                s.rounds.fmt(1),
            ]);
            csv.push_str(&format!(
                "{sampler_name},{strategy},{SEEDS},{:.4},{:.4},{:.4},{:.1},{:.1},{:.4}\n",
                s.mean_participation.mean,
                s.mean_participation.std,
                share.mean,
                s.avail_drops.mean,
                s.deadline_drops.mean,
                s.mean_online_fraction.mean,
            ));
            stats.push((sampler_name.to_string(), strategy, s.mean_participation, share));
        }
    }

    let rendered = t.render();
    println!("{rendered}");

    // Per-strategy stay-prob vs uniform deltas — the acceptance shape.
    let lookup = |sampler: &str, strategy: &str| {
        stats
            .iter()
            .find(|(sa, st, _, _)| sa == sampler && st == strategy)
            .map(|(_, _, p, sh)| (*p, *sh))
            .expect("cell missing from stats")
    };
    let mut summary = rendered;
    println!("stay-prob vs uniform, per strategy (positive participation delta = sampler wins):");
    for c in &result.cells[..n_strategies] {
        let strategy = c.cell.cfg.strategy.as_str();
        let (pu, su) = lookup("uniform", strategy);
        let (ps, ss) = lookup("stay-prob", strategy);
        let line = format!(
            "  {strategy:>9}: participation {:+.3} ({:.3} -> {:.3}), avail share {:+.3} ({:.3} -> {:.3})",
            ps.mean - pu.mean,
            pu.mean,
            ps.mean,
            ss.mean - su.mean,
            su.mean,
            ss.mean,
        );
        println!("{line}");
        summary.push_str(&line);
        summary.push('\n');
    }
    println!(
        "expected shape: stay-prob raises participation and lowers the availability-drop\n\
         share vs uniform under correlated churn; uniform under always-on availability\n\
         stays bit-identical to the committed goldens."
    );

    benchkit::write_result("sampler_regional_churn.txt", &summary);
    benchkit::write_result("sampler_regional_churn.csv", &csv);
    Ok(())
}
