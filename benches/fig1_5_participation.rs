//! Figures 1a/1b + 5 — per-client participation rate, TimelyFL vs FedBuff
//! (vs SyncFL as the all-inclusive reference).
//!
//! Paper claims (CIFAR-10 setting): TimelyFL raises the AVERAGE
//! participation rate by ~21% relative to FedBuff, and 66.4% of devices
//! individually improve. SyncFL is 100% by construction (everyone waits).
//!
//! Declared as a scenario + grid (`experiment` API): the `cifar` scenario
//! with one strategy axis, executed by the parallel `ExperimentRunner`.
//!
//! Prints: mean participation per strategy, the improved-devices fraction,
//! and the per-client rate distribution (sorted deciles — the shape of the
//! paper's Fig. 5a scatter).

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::experiment::{scenario, SweepGrid};
use timelyfl::metrics::report::{participation_table, Table};
use timelyfl::metrics::RunReport;

fn deciles(mut rates: Vec<f64>) -> Vec<f64> {
    rates.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (0..=10)
        .map(|i| rates[((rates.len() - 1) * i) / 10])
        .collect()
}

fn main() -> Result<()> {
    benchkit::banner(
        "fig1_5_participation",
        "Figs. 1a/1b/5 (participation rate: +21% mean, 66.4% of devices improve)",
    );
    let bench = Bench::new()?;

    let mut base = scenario::resolve("cifar")?.config()?;
    base.rounds = bench.scale.rounds(150);
    base.eval_every = 50;
    eprintln!("  TimelyFL/FedBuff/SyncFL (rounds={}) ...", base.rounds);
    let grid = SweepGrid::new(base).axis("strategy", &["TimelyFL", "FedBuff", "SyncFL"]);
    let reports: Vec<RunReport> = bench.runner().run(&grid)?.into_first_reports();
    let [timely, fedbuff, syncfl] = &reports[..] else { unreachable!() };

    // Fig. 1a/1b analogue: mean participation + distribution deciles.
    let mut t = Table::new(&[
        "strategy",
        "mean_participation",
        "p10",
        "p50",
        "p90",
        "min",
        "max",
    ]);
    for r in &reports {
        let d = deciles(r.participation.clone());
        t.row(vec![
            r.strategy.clone(),
            format!("{:.3}", r.mean_participation()),
            format!("{:.3}", d[1]),
            format!("{:.3}", d[5]),
            format!("{:.3}", d[9]),
            format!("{:.3}", d[0]),
            format!("{:.3}", d[10]),
        ]);
    }
    let rendered = t.render();
    println!("{rendered}");

    // Drop attribution: with the default always-on process online_frac is
    // 1.0 and avail_drops 0 — the columns matter for the churn sweeps
    // (see benches/fig10_availability_sweep.rs).
    let rows: Vec<(&str, &RunReport)> =
        reports.iter().map(|r| (r.strategy.as_str(), r)).collect();
    println!("{}", participation_table(&rows).render());

    // Fig. 5b analogue: paired per-client comparison.
    let improved = timely
        .participation
        .iter()
        .zip(&fedbuff.participation)
        .filter(|(a, b)| a > b)
        .count() as f64
        / timely.participation.len() as f64;
    let mean_gain = timely.mean_participation() - fedbuff.mean_participation();
    let rel_gain = mean_gain / fedbuff.mean_participation().max(1e-9) * 100.0;

    println!("TimelyFL vs FedBuff:");
    println!("  devices with improved participation: {:.1}% (paper: 66.4%)", improved * 100.0);
    println!(
        "  mean participation: {:.3} vs {:.3} (+{rel_gain:.1}% relative; paper: +21.1%)",
        timely.mean_participation(),
        fedbuff.mean_participation()
    );
    println!(
        "  SyncFL reference mean: {:.3} (1.0 by construction)",
        syncfl.mean_participation()
    );

    // Per-client CSV for plotting (client_id, timelyfl, fedbuff, syncfl).
    let mut csv = String::from("client,timelyfl,fedbuff,syncfl\n");
    for i in 0..timely.participation.len() {
        csv.push_str(&format!(
            "{i},{:.4},{:.4},{:.4}\n",
            timely.participation[i], fedbuff.participation[i], syncfl.participation[i]
        ));
    }
    benchkit::write_result("fig1_5_participation.csv", &csv);
    benchkit::write_result(
        "fig1_5_participation.txt",
        &format!(
            "{rendered}\nimproved={:.3} rel_gain={rel_gain:.1}%\n",
            improved
        ),
    );
    Ok(())
}
