//! Figure 9 — partial-training time vs ratio linearity.
//!
//! Paper (Appendix A.2.1): on a Galaxy S20 + MNN, ResNet-20 training time
//! is ≈ linear in the partial-training ratio (slightly BELOW the straight
//! line except at very small ratios, where fixed overheads dominate). That
//! linearity is the modelling assumption behind Algorithm 3's alpha rule.
//!
//! We measure the same claim on our substrate: real wall-clock of the
//! compiled partial train-step executables (PJRT CPU) per ratio, normalised
//! to the full-model time, for the vision and speech models.
//!
//! Declared as a one-axis grid (`model` = vision/speech) over the cifar
//! scenario, executed via `ExperimentRunner::map` — pinned serial, because
//! the measurement is wall-clock.

use anyhow::Result;
use timelyfl::benchkit::{self, micro, Bench};
use timelyfl::experiment::{scenario, SweepGrid};
use timelyfl::metrics::report::Table;
use timelyfl::util::rng::Rng;

fn main() -> Result<()> {
    benchkit::banner(
        "fig9_partial_linearity",
        "Fig. 9 (partial-training time ~ linear in ratio; measured, not assumed)",
    );
    let bench = Bench::new()?;
    let iters = bench.scale.iters(40);

    let mut base = scenario::resolve("cifar")?.config()?;
    base.population = 8;
    base.concurrency = 2;
    let grid = SweepGrid::new(base).axis("model", &["vision", "speech"]);

    // Per cell: (model name, total params, per-ratio rows of
    // (ratio, trainable_fraction, mean_ns)).
    let measured = bench.serial_runner().map(&grid, |sim, _job| {
        let rt = &sim.runtime;
        let params = rt.init_params(0)?;
        let mut rng = Rng::seed_from(9);
        let batches: Vec<_> = (0..rt.meta.chunk)
            .map(|_| sim.dataset.train_batch(0, &mut rng))
            .collect();

        // Measure each compiled ratio with an identical chunk workload.
        let mut rows = Vec::new();
        for r in &rt.meta.ratios {
            let stats = micro::bench(3, iters, || {
                let out = rt.train_chunk(r, &params, &batches, 0.01).unwrap();
                std::hint::black_box(out);
            });
            rows.push((r.ratio, r.trainable_fraction, stats.mean_ns));
        }
        Ok((rt.meta.name.clone(), rt.meta.total_params, rows))
    })?;

    let mut csv = String::from("model,ratio,trainable_fraction,mean_ms,relative\n");
    for cell in &measured {
        let (model, total_params, rows) = &cell[0];
        println!("--- {model} ({total_params} params) ---");
        let full = rows.last().unwrap().2; // ratio 1.0 is last (sorted in manifest)

        let mut t = Table::new(&[
            "ratio",
            "trainable_frac",
            "mean time",
            "relative",
            "linear pred",
            "below line?",
        ]);
        for &(ratio, frac, ns) in rows {
            let rel = ns / full;
            // The paper's linear model predicts fwd+bwd time ∝ ratio with a
            // fixed forward-pass floor: rel ≈ fwd_share + (1-fwd_share)*ratio.
            t.row(vec![
                format!("{ratio}"),
                format!("{frac:.3}"),
                micro::MicroStats::fmt(ns),
                format!("{rel:.3}"),
                format!("{ratio:.3}"),
                if rel <= ratio + 0.15 { "yes".into() } else { "no".into() },
            ]);
            csv.push_str(&format!(
                "{model},{ratio},{frac:.4},{:.3},{rel:.4}\n",
                ns / 1e6
            ));
        }
        let rendered = t.render();
        println!("{rendered}");
        benchkit::write_result(&format!("fig9_partial_linearity_{model}.txt"), &rendered);
    }
    benchkit::write_result("fig9_partial_linearity.csv", &csv);
    println!(
        "paper shape: measured time tracks the linear-in-ratio model (most points at or\n\
         below the line; small ratios sit above it because the frozen forward pass and\n\
         per-call overheads do not shrink)."
    );
    Ok(())
}
