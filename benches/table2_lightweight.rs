//! Table 2 — wall-clock time-to-accuracy on the LIGHTWEIGHT keyword-spotting
//! model (paper §4.3 "TimelyFL is effective on the lightweight model";
//! conv+GRU net, 79k params, Google Speech, concurrency 106).
//!
//! With a tiny model, communication is cheap and the system is compute-
//! dominated; the paper still reports TimelyFL first to every target
//! (1.47-3.46x vs FedBuff, 6.6-9.6x vs SyncFL). Same shape target here.

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::experiment::{scenario, SweepGrid};
use timelyfl::metrics::report::{fmt_hours, fmt_speedup, Table};
use timelyfl::metrics::RunReport;

const TARGETS: [(&str, f64); 2] = [("50%", 0.50), ("65%", 0.65)];
/// The paper's Table 2 column layout (registry names, fixed order).
const STRATEGIES: [&str; 3] = ["TimelyFL", "FedBuff", "SyncFL"];

fn main() -> Result<()> {
    benchkit::banner(
        "table2_lightweight",
        "Table 2 (lightweight KWS model, FedAvg + FedOpt, 3 strategies)",
    );
    let bench = Bench::new()?;
    let mut out = Table::new(&[
        "agg",
        "target",
        "TimelyFL",
        "FedBuff",
        "SyncFL",
        "best T/F/S",
    ]);
    let mut csv = String::from("agg,target,timelyfl_hr,fedbuff_hr,syncfl_hr\n");

    for preset in ["kws_fedavg", "kws_fedopt"] {
        let agg = preset.rsplit('_').next().unwrap();
        // Scenario + strategy-axis grid per aggregator, parallel cells.
        let mut base = scenario::resolve(preset)?.config()?;
        base.rounds = bench.scale.rounds(220);
        base.eval_every = 10;
        base.target_metric = Some(TARGETS[1].1);
        eprintln!("  {preset} / {} (rounds<={}) ...", STRATEGIES.join("/"), base.rounds);
        let grid = SweepGrid::new(base).axis("strategy", &STRATEGIES);
        let reports: Vec<RunReport> = bench.runner().run(&grid)?.into_first_reports();

        for (tname, tval) in TARGETS {
            let times: Vec<Option<f64>> =
                reports.iter().map(|r| r.time_to_target(tval, true)).collect();
            out.row(vec![
                agg.into(),
                tname.into(),
                fmt_hours(times[0]),
                format!("{} {}", fmt_hours(times[1]), fmt_speedup(times[0], times[1])),
                format!("{} {}", fmt_hours(times[2]), fmt_speedup(times[0], times[2])),
                reports
                    .iter()
                    .map(|r| r.best_metric(true).map(|m| format!("{m:.3}")).unwrap_or_default())
                    .collect::<Vec<_>>()
                    .join("/"),
            ]);
            let h = |t: Option<f64>| t.map(|v| format!("{v:.3}")).unwrap_or_else(|| ">budget".into());
            csv.push_str(&format!(
                "{agg},{tname},{},{},{}\n",
                h(times[0]),
                h(times[1]),
                h(times[2])
            ));
        }
    }

    let rendered = out.render();
    println!("{rendered}");
    println!("paper shape: TimelyFL first everywhere; FedBuff 1.47-3.46x, SyncFL 6.61-9.60x.");
    benchkit::write_result("table2_lightweight.txt", &rendered);
    benchkit::write_result("table2_lightweight.csv", &csv);
    Ok(())
}
