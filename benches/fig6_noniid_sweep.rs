//! Figure 6 — convergence gap vs non-iid severity (Dirichlet alpha sweep).
//!
//! Paper: TimelyFL's advantage over FedBuff GROWS as the data gets less
//! iid (smaller alpha), because inclusiveness matters most when every
//! client holds a unique slice of the distribution. We sweep
//! alpha in {0.1, 0.5, 1.0} on the vision workload with FedAvg (the
//! paper's Fig. 6 setting) and report time-to-target + final accuracy for
//! both strategies per alpha.

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::experiment::{scenario, SweepGrid};
use timelyfl::metrics::report::{fmt_hours, fmt_speedup, Table};

const TARGET: f64 = 0.40;
const ALPHAS: [f64; 3] = [0.1, 0.5, 1.0];

fn main() -> Result<()> {
    benchkit::banner(
        "fig6_noniid_sweep",
        "Fig. 6 (TimelyFL-vs-FedBuff gap across Dirichlet alpha)",
    );
    let bench = Bench::new()?;
    let mut t = Table::new(&[
        "alpha",
        "TimelyFL t40%",
        "FedBuff t40%",
        "speedup",
        "final T",
        "final F",
        "final gap",
    ]);
    let mut csv = String::from("alpha,timelyfl_hr,fedbuff_hr,final_timelyfl,final_fedbuff\n");

    // One grid: non-iid severity x the two compared strategies.
    let mut base = scenario::resolve("cifar")?.config()?;
    base.rounds = bench.scale.rounds(180);
    base.eval_every = 10;
    eprintln!("  alpha x strategy grid, 6 cells (rounds={}) ...", base.rounds);
    let grid = SweepGrid::new(base)
        .axis("dirichlet_alpha", &ALPHAS)
        .axis("strategy", &["TimelyFL", "FedBuff"]);
    let result = bench.runner().run(&grid)?;

    for (ai, alpha) in ALPHAS.into_iter().enumerate() {
        let mut times = Vec::new();
        let mut finals = Vec::new();
        for (si, strat) in ["TimelyFL", "FedBuff"].into_iter().enumerate() {
            let r = &result.cells[ai * 2 + si].reports[0];
            assert_eq!(r.strategy, strat, "grid order drifted");
            benchkit::write_result(
                &format!("fig6_curve_a{alpha}_{}.csv", strat.to_lowercase()),
                &r.curve_csv(),
            );
            times.push(r.time_to_target(TARGET, true));
            finals.push(r.best_metric(true).unwrap_or(0.0));
        }
        t.row(vec![
            format!("{alpha}"),
            fmt_hours(times[0]),
            fmt_hours(times[1]),
            fmt_speedup(times[0], times[1]),
            format!("{:.3}", finals[0]),
            format!("{:.3}", finals[1]),
            format!("{:+.3}", finals[0] - finals[1]),
        ]);
        let h = |t: Option<f64>| t.map(|v| format!("{v:.3}")).unwrap_or_else(|| ">budget".into());
        csv.push_str(&format!(
            "{alpha},{},{},{:.4},{:.4}\n",
            h(times[0]),
            h(times[1]),
            finals[0],
            finals[1]
        ));
    }

    let rendered = t.render();
    println!("{rendered}");
    println!("paper shape: the TimelyFL advantage (speedup + accuracy gap) grows as alpha shrinks.");
    benchkit::write_result("fig6_noniid_sweep.txt", &rendered);
    benchkit::write_result("fig6_noniid_sweep.csv", &csv);
    Ok(())
}
