//! §Perf — micro-benchmarks of every L3 hot path plus the PJRT execution
//! boundary (in-tree harness; criterion itself is not in the offline
//! vendor set, so `benchkit::micro` provides warmup + percentile stats).
//!
//! Runs as a one-cell grid over the cifar scenario through
//! `ExperimentRunner::map` — pinned serial: every number here is
//! wall-clock, so co-running cells would skew it.
//!
//! Paths covered (the before/after log lives in EXPERIMENTS.md §Perf):
//!   - aggregation: `average_delta` over a full concurrency cohort
//!   - server optimizers: FedAvg apply vs Adam step
//!   - update algebra: `delta_from` / `apply`
//!   - scheduler: Alg. 3 for a full cohort + T_k selection
//!   - event queue: schedule/pop churn at FedBuff scale
//!   - fleet: generation + per-round condition draws
//!   - data: synthetic batch materialisation
//!   - runtime: train chunk (1 vs 8 fused steps), eval batch — the PJRT
//!     call overhead that motivated the L2 scan fusion
//!   - batched execution: one stacked `train_chunk_batched` dispatch vs
//!     `lanes` serial dispatches of the same work (the `batch_exec` win)
//!   - chunk-parallel aggregation: `average_delta_jobs` at 1/2/4 workers
//!     (bit-identical by construction; this measures the speedup)
//!
//! Plus one END-TO-END A/B on the `fleet_50k` scenario: `batch_exec=off`
//! vs `on`, recording wall time and the PJRT dispatch count
//! (`RuntimeStats::train_execs`) into `results/BENCH_hotpath.json` with a
//! `dispatch_reduction` headline (schema: results/README.md).

use std::time::Instant;

use anyhow::Result;
use timelyfl::aggregation::{
    average_delta, average_delta_jobs, Contribution, ServerOpt, ServerOptKind,
};
use timelyfl::benchkit::{self, micro, Bench};
use timelyfl::coordinator::local_time::TimeEstimate;
use timelyfl::coordinator::scheduler::{aggregation_interval, schedule};
use timelyfl::devices::{Fleet, FleetConfig};
use timelyfl::experiment::{scenario, SweepGrid};
use timelyfl::metrics::report::Table;
use timelyfl::model::{ParamVec, Update};
use timelyfl::runtime::Batch;
use timelyfl::simtime::EventQueue;
use timelyfl::util::json::Json;
use timelyfl::util::rng::Rng;

fn synth_params(meta: &timelyfl::runtime::manifest::ModelMeta, rng: &mut Rng) -> ParamVec {
    ParamVec {
        tensors: meta
            .params
            .iter()
            .map(|p| (0..p.size).map(|_| rng.range(-0.1, 0.1) as f32).collect())
            .collect(),
    }
}

fn main() -> Result<()> {
    benchkit::banner("hotpath_criterion", "§Perf hot-path micro-benchmarks");
    let bench = Bench::new()?;
    let iters = bench.scale.iters(60);

    let mut base = scenario::resolve("cifar")?.config()?;
    base.population = 16;
    base.concurrency = 8;
    let grid = SweepGrid::new(base); // one cell: the base scenario

    let measured = bench.serial_runner().map(&grid, |sim, _job| {
        let mut rows: Vec<Vec<String>> = Vec::new();
        let mut rng = Rng::seed_from(0xBE7C);
        let meta = &sim.runtime.meta;

        // --- pure-rust hot paths (no PJRT) --------------------------------
        let base = synth_params(meta, &mut rng);
        let cohort = 32;
        let contributions: Vec<Contribution> = (0..cohort)
            .map(|i| {
                let new = synth_params(meta, &mut rng);
                Contribution {
                    client_id: i,
                    update: new.delta_from(&base, if i % 3 == 0 { 4 } else { 0 }),
                    weight: 1.0,
                    staleness: (i % 5) as u64,
                }
            })
            .collect();

        rows.push(
            micro::bench(5, iters, || {
                std::hint::black_box(average_delta(&base, &contributions, true));
            })
            .row(&format!("average_delta n={cohort} ({} params)", meta.total_params)),
        );

        // Chunk-parallel fold (tensor-index partition; bit-identical to the
        // serial row above — parallel_agg_properties proves it, this
        // measures it).
        for jobs in [2usize, 4] {
            rows.push(
                micro::bench(5, iters, || {
                    std::hint::black_box(average_delta_jobs(&base, &contributions, true, jobs));
                })
                .row(&format!("average_delta_jobs n={cohort} jobs={jobs}")),
            );
        }

        let avg: Update = average_delta(&base, &contributions, false);
        let mut fedavg = ServerOpt::new(ServerOptKind::FedAvg, 1.0);
        let mut adam = ServerOpt::new(ServerOptKind::Adam, 0.001);
        let mut g1 = base.clone();
        rows.push(
            micro::bench(5, iters, || {
                fedavg.apply(&mut g1, &avg);
                std::hint::black_box(&g1);
            })
            .row("server_opt FedAvg apply"),
        );
        let mut g2 = base.clone();
        rows.push(
            micro::bench(5, iters, || {
                adam.apply(&mut g2, &avg);
                std::hint::black_box(&g2);
            })
            .row("server_opt Adam step"),
        );

        let other = synth_params(meta, &mut rng);
        rows.push(
            micro::bench(5, iters, || {
                std::hint::black_box(other.delta_from(&base, 0));
            })
            .row("delta_from full model"),
        );

        // Scheduler: full cohort of Alg. 3 + T_k.
        let estimates: Vec<TimeEstimate> = (0..128)
            .map(|_| TimeEstimate {
                t_cmp: rng.range(10.0, 800.0),
                t_com: rng.range(1.0, 400.0),
            })
            .collect();
        let totals: Vec<f64> = estimates.iter().map(|e| e.t_total()).collect();
        rows.push(
            micro::bench(5, iters * 10, || {
                let tk = aggregation_interval(&totals, 64);
                for e in &estimates {
                    std::hint::black_box(schedule(tk, e, 16));
                }
            })
            .row("Alg.3 schedule cohort n=128"),
        );

        // Event queue churn at FedBuff scale.
        rows.push(
            micro::bench(5, iters, || {
                let mut q: EventQueue<usize> = EventQueue::new();
                for i in 0..4096 {
                    q.schedule_in((i % 97) as f64, i);
                }
                while let Some(e) = q.pop() {
                    std::hint::black_box(e);
                }
            })
            .row("event queue 4096 sched+pop"),
        );

        rows.push(
            micro::bench(2, iters, || {
                let mut r = Rng::seed_from(1);
                std::hint::black_box(Fleet::generate(1024, FleetConfig::default(), &mut r));
            })
            .row("fleet generate n=1024"),
        );

        let fleet = Fleet::generate(128, FleetConfig::default(), &mut rng);
        rows.push(
            micro::bench(5, iters * 10, || {
                for _ in 0..128 {
                    std::hint::black_box(fleet.round_conditions(&mut rng));
                }
            })
            .row("round_conditions x128"),
        );

        rows.push(
            micro::bench(5, iters, || {
                std::hint::black_box(sim.dataset.train_batch(3, &mut rng));
            })
            .row("synthetic train_batch"),
        );

        // --- PJRT boundary ------------------------------------------------
        let rt = &sim.runtime;
        let params = rt.init_params(0)?;
        let full = rt.meta.ratio_exact(1.0).unwrap();
        let batches: Vec<_> = (0..rt.meta.chunk)
            .map(|_| sim.dataset.train_batch(0, &mut rng))
            .collect();

        rows.push(
            micro::bench(3, iters, || {
                std::hint::black_box(rt.train_chunk(full, &params, &batches[..1], 0.01).unwrap());
            })
            .row("PJRT train chunk of 1 step"),
        );
        rows.push(
            micro::bench(3, iters, || {
                std::hint::black_box(rt.train_chunk(full, &params, &batches, 0.01).unwrap());
            })
            .row(&format!("PJRT train chunk of {} steps", rt.meta.chunk)),
        );
        let eval_batches = sim.dataset.eval_batches(1, 0);
        rows.push(
            micro::bench(3, iters, || {
                std::hint::black_box(rt.eval_batch(&params, &eval_batches[0]).unwrap());
            })
            .row("PJRT eval batch"),
        );

        // Batched execution boundary: one stacked dispatch carrying `lanes`
        // clients' chunks vs the same work as `lanes` serial dispatches.
        // Gated on the manifest actually carrying batched graphs (older
        // artifact sets predate them — the lanes just skip).
        if rt.meta.lanes >= 1 {
            let lanes = rt.meta.lanes;
            let lane_args: Vec<(&ParamVec, &[Batch])> =
                (0..lanes).map(|_| (&params, &batches[..])).collect();
            rows.push(
                micro::bench(3, iters, || {
                    std::hint::black_box(
                        rt.train_chunk_batched(full, &lane_args, 0.01).unwrap(),
                    );
                })
                .row(&format!("PJRT batched chunk, {lanes} lanes / 1 dispatch")),
            );
            rows.push(
                micro::bench(3, iters, || {
                    for _ in 0..lanes {
                        std::hint::black_box(
                            rt.train_chunk(full, &params, &batches, 0.01).unwrap(),
                        );
                    }
                })
                .row(&format!("PJRT serial chunks, {lanes} dispatches")),
            );
        }

        Ok((rt.meta.chunk, rows))
    })?;

    let (chunk, rows) = &measured[0][0];
    let mut table = Table::new(&micro::MicroStats::HEADERS);
    for row in rows {
        table.row(row.clone());
    }
    let rendered = table.render();
    println!("{rendered}");
    println!(
        "note: 'chunk of {chunk} steps' vs {chunk}x 'chunk of 1' shows the scan fusion win\n\
         (per-execute dispatch + host<->device copies amortised across local steps)."
    );
    benchkit::write_result("hotpath_micro.txt", &rendered);

    // --- end-to-end A/B: fleet_50k, batch_exec off vs on ------------------
    // Same seed, same semantics (batched_equivalence.rs proves the reports
    // byte-identical); what changes is the PJRT dispatch count and wall
    // time. Fast mode downscales to the CI smoke shape.
    let mut e2e = scenario::resolve("fleet_50k")?.config()?;
    if bench.scale.fast {
        e2e.population = 2_000;
        e2e.concurrency = 16;
        e2e.rounds = 2;
        e2e.eval_every = 2;
    }
    let mut points = Vec::new();
    let mut execs = Vec::new();
    for batched in [false, true] {
        let mut cfg = e2e.clone();
        cfg.batch_exec = batched;
        cfg.agg_jobs = if batched { 2 } else { 1 };
        let variant = if batched { "batched" } else { "serial" };
        let sim = bench.simulation(cfg)?;
        if batched && sim.runtime.meta.lanes == 0 {
            eprintln!("  fleet_50k / batched: skipped (artifact set has no batched graphs)");
            continue;
        }
        eprintln!("  fleet_50k / {variant} ...");
        let start = Instant::now();
        let report = sim.run()?;
        let wall = start.elapsed().as_secs_f64();
        let stats = sim.runtime.stats();
        execs.push(stats.train_execs);
        points.push(Json::obj(vec![
            ("variant", Json::str(variant)),
            ("batch_exec", Json::Bool(batched)),
            ("agg_jobs", Json::num(if batched { 2.0 } else { 1.0 })),
            ("wall_secs", Json::num(wall)),
            ("sim_secs", Json::num(report.sim_secs)),
            ("rounds", Json::num(report.total_rounds as f64)),
            ("train_steps", Json::num(stats.train_steps as f64)),
            ("train_execs", Json::num(stats.train_execs as f64)),
        ]));
    }
    // Headline: how many serial PJRT dispatches one batched dispatch
    // replaced (>1.0 is the win; the logical step count is unchanged).
    let reduction = match execs.as_slice() {
        [serial, batched] if *batched > 0 => Json::num(*serial as f64 / *batched as f64),
        _ => Json::Null,
    };
    let json = Json::obj(vec![
        ("bench", Json::str("hotpath")),
        ("scenario", Json::str("fleet_50k")),
        ("fast", Json::Bool(bench.scale.fast)),
        ("dispatch_reduction", reduction),
        ("points", Json::arr(points)),
    ]);
    benchkit::write_result("BENCH_hotpath.json", &json.to_string());
    Ok(())
}
