//! Network-dissemination bench — what the downlink leg costs, and what
//! bandwidth-aware rebalancing buys back.
//!
//! Three claims from the network subsystem (`crate::network`), measured on
//! a small correlated-churn fleet:
//!
//! 1. `network = free` is the historical behaviour: downlink-wait and
//!    stale-start counters are exactly zero for every strategy.
//! 2. `network = priced` makes every dispatch pay a downlink leg priced by
//!    the client's *current* bandwidth factor, so the run-level
//!    `downlink_wait_secs` is nonzero everywhere and the event-driven
//!    strategies (FedBuff, SemiAsyncFL) additionally record stale starts —
//!    dispatches whose transfer was overtaken by a newer global version.
//! 3. With `net_rebalance = true`, TimelyFL's Alg. 3 schedules against the
//!    *effective* (bandwidth-degraded) timeline, shrinking the mean E_c /
//!    alpha_c it assigns versus the nominal schedule — trading workload for
//!    deadline survival exactly as the adaptive partial-training story says.
//!
//! Output: an aligned table on stdout plus `results/BENCH_network.json`
//! (schema in `results/README.md`) with one point per (strategy, network
//! variant): downlink-wait seconds, stale starts, drop attribution, and the
//! mean scheduled workload pulled from the run-event stream's per-round
//! `workloads` records.

use std::time::Instant;

use anyhow::Result;
use timelyfl::availability::AvailabilityKind;
use timelyfl::benchkit::{self, Bench};
use timelyfl::config::RunConfig;
use timelyfl::coordinator::registry;
use timelyfl::metrics::events::{CollectSink, RunEvent};
use timelyfl::metrics::report::Table;
use timelyfl::network::StaleCorrection;
use timelyfl::util::json::Json;

/// Tiny correlated-churn fleet: regional outages plus the degrade-before-
/// drop bandwidth ramp, so the priced downlink has real weather to price.
fn base_cfg(strategy: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "kws_lite".into();
    cfg.strategy = strategy.to_string();
    cfg.population = 24;
    cfg.concurrency = 8;
    cfg.rounds = 12;
    cfg.eval_every = 6;
    cfg.eval_batches = 1;
    cfg.steps_per_epoch = 1;
    cfg.max_local_epochs = 4;
    cfg.sim_model_bytes = 3.2e5;
    cfg.availability.kind = AvailabilityKind::Correlated;
    cfg.availability.regions = 3;
    cfg.availability.region_mtbf_secs = 500.0;
    cfg.availability.region_outage_secs = 250.0;
    cfg.availability.mean_online_secs = 600.0;
    cfg.availability.mean_offline_secs = 200.0;
    cfg.availability.degrade_window_secs = 120.0;
    cfg.sampler_horizon_secs = 200.0;
    cfg
}

/// One bench variant: a config mutation on top of `base_cfg` plus a label.
struct Variant {
    label: &'static str,
    network: &'static str,
    rebalance: bool,
    stale_correction: StaleCorrection,
}

const VARIANTS: &[Variant] = &[
    Variant {
        label: "free",
        network: "free",
        rebalance: false,
        stale_correction: StaleCorrection::None,
    },
    Variant {
        label: "priced",
        network: "priced",
        rebalance: false,
        stale_correction: StaleCorrection::None,
    },
];

/// Extra TimelyFL-only variants: the rebalancing claim is about Alg. 3.
const TIMELYFL_VARIANTS: &[Variant] = &[
    Variant {
        label: "priced+rebalance",
        network: "priced",
        rebalance: true,
        stale_correction: StaleCorrection::None,
    },
    Variant {
        label: "priced+rebalance+replay",
        network: "priced",
        rebalance: true,
        stale_correction: StaleCorrection::DeltaReplay,
    },
];

/// Mean scheduled workload over every dispatch in the event stream.
fn mean_workload(events: &[RunEvent]) -> (f64, f64, usize) {
    let mut epochs = 0.0;
    let mut alpha = 0.0;
    let mut n = 0usize;
    for ev in events {
        if let RunEvent::RoundComplete { workloads, .. } = ev {
            for w in workloads {
                epochs += w.epochs as f64;
                alpha += w.alpha;
                n += 1;
            }
        }
    }
    if n == 0 {
        (0.0, 0.0, 0)
    } else {
        (epochs / n as f64, alpha / n as f64, n)
    }
}

fn main() -> Result<()> {
    benchkit::banner(
        "network_dissemination",
        "downlink dissemination cost + bandwidth-aware rebalancing (Alg. 3 on the effective timeline)",
    );
    let bench = Bench::new()?;

    let mut table = Table::new(&[
        "strategy",
        "variant",
        "downlink_wait_s",
        "stale_starts",
        "mean_E_c",
        "mean_alpha_c",
        "avail_drops",
        "deadline_drops",
        "sim_hours",
    ]);
    let mut points = Vec::new();
    // (mean_epochs, mean_alpha) for the TimelyFL priced-but-nominal
    // schedule, to state the rebalancing delta explicitly at the end.
    let mut timelyfl_nominal: Option<(f64, f64)> = None;
    let mut timelyfl_rebalanced: Option<(f64, f64)> = None;

    for info in registry::STRATEGIES {
        let variants: Vec<&Variant> = if info.name == "TimelyFL" {
            VARIANTS.iter().chain(TIMELYFL_VARIANTS).collect()
        } else {
            VARIANTS.iter().collect()
        };
        for v in variants {
            let mut cfg = base_cfg(info.name);
            cfg.rounds = bench.scale.rounds(cfg.rounds).min(cfg.rounds);
            cfg.network.model = v.network.into();
            // A substantial downlink (down_ratio 1.0: the model costs as
            // much to receive as to upload) so transfer windows are long
            // enough for newer globals to land mid-flight.
            cfg.network.down_ratio = 1.0;
            cfg.network.rebalance = v.rebalance;
            cfg.network.stale_correction = v.stale_correction;
            eprintln!("  {} / {} ...", info.name, v.label);
            let sim = bench.simulation(cfg)?;
            let mut sink = CollectSink::default();
            let start = Instant::now();
            let report = sim.run_with_sink(&mut sink)?;
            let wall = start.elapsed().as_secs_f64();
            let (mean_epochs, mean_alpha, dispatches) = mean_workload(&sink.events);
            if info.name == "TimelyFL" {
                match v.label {
                    "priced" => timelyfl_nominal = Some((mean_epochs, mean_alpha)),
                    "priced+rebalance" => {
                        timelyfl_rebalanced = Some((mean_epochs, mean_alpha))
                    }
                    _ => {}
                }
            }
            table.row(vec![
                info.name.into(),
                v.label.into(),
                format!("{:.1}", report.downlink_wait_secs),
                report.stale_starts.to_string(),
                format!("{mean_epochs:.2}"),
                format!("{mean_alpha:.3}"),
                report.total_avail_drops().to_string(),
                report.total_deadline_drops().to_string(),
                format!("{:.2}", report.sim_secs / 3600.0),
            ]);
            points.push(Json::obj(vec![
                ("strategy", Json::str(info.name)),
                ("variant", Json::str(v.label)),
                ("network", Json::str(v.network)),
                ("rebalance", Json::Bool(v.rebalance)),
                ("stale_correction", Json::str(v.stale_correction.name())),
                ("downlink_wait_secs", Json::num(report.downlink_wait_secs)),
                ("stale_starts", Json::num(report.stale_starts as f64)),
                ("mean_epochs", Json::num(mean_epochs)),
                ("mean_alpha", Json::num(mean_alpha)),
                ("dispatches", Json::num(dispatches as f64)),
                ("avail_drops", Json::num(report.total_avail_drops() as f64)),
                ("deadline_drops", Json::num(report.total_deadline_drops() as f64)),
                ("sim_secs", Json::num(report.sim_secs)),
                ("rounds", Json::num(report.total_rounds as f64)),
                ("wall_secs", Json::num(wall)),
            ]));
        }
    }

    let rendered = table.render();
    println!("{rendered}");
    if let (Some((ne, na)), Some((re, ra))) = (timelyfl_nominal, timelyfl_rebalanced) {
        println!(
            "rebalancing delta (TimelyFL, priced): mean E_c {ne:.2} -> {re:.2}, \
             mean alpha_c {na:.3} -> {ra:.3}\n\
             (scheduling against the degraded timeline must not INCREASE the \
             assigned workload: `degraded()` only stretches the comm term, and \
             Alg. 3 is monotone in the estimate)"
        );
    }
    println!(
        "shape target: free rows pin both counters to zero; priced rows pay a \
         nonzero downlink everywhere,\nwith stale starts on the event-driven \
         strategies whose transfers a newer global can overtake."
    );
    let json = Json::obj(vec![
        ("bench", Json::str("network_dissemination")),
        ("fleet", Json::str("correlated 3-region, pop 24, conc 8")),
        ("down_ratio", Json::num(1.0)),
        ("points", Json::arr(points)),
    ]);
    benchkit::write_result("BENCH_network.json", &json.to_string());
    benchkit::write_result("network_dissemination.txt", &rendered);
    Ok(())
}
