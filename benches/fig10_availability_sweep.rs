//! "Figure 10" (new scenario, beyond the paper) — participation under
//! client churn: every registered strategy swept across mean
//! online-fraction.
//!
//! The paper's participation claim (Figs. 1/5: +21.1% mean participation
//! vs FedBuff) is measured against an always-reachable population. Here the
//! fleet churns through a Markov on/off availability process and we shrink
//! the mean online fraction from 1.0 (always-on) downwards. Expected shape:
//! TimelyFL's participation-rate advantage over FedBuff WIDENS as
//! availability drops — FedBuff's k-fastest-arrivals aggregation compounds
//! with churn (slow clients now also churn out mid-training and lose their
//! in-flight updates), while TimelyFL re-samples from whoever is online and
//! right-sizes their workload.
//!
//! The whole study is one grid: `avail_frac` axis × the full strategy
//! registry (a newly-registered strategy joins with zero bench changes),
//! cells executed in parallel by `ExperimentRunner`. The same sweep is one
//! CLI line:
//! `timelyfl sweep --scenario cifar --axis avail_frac=1.0,0.8,0.5,0.3 --axis strategy=...`.
//!
//! Prints one row per (online-fraction, strategy) with the availability
//! columns (online_frac, avail_drops, deadline_drops) plus the per-setting
//! TimelyFL-vs-FedBuff participation gap. Every cell is replicated over
//! [`SEEDS`] seeds by the experiment runner and reported as mean ± std.

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::experiment::{scenario, SweepGrid};
use timelyfl::metrics::report::Table;

/// Target mean online fractions; 1.0 is the always-on control.
const FRACTIONS: &[f64] = &[1.0, 0.8, 0.5, 0.3];
/// Seed replicates per cell (mean ± std in every reported column).
const SEEDS: usize = 3;
/// One full on+off cycle, comparable to a handful of round intervals so
/// churn actually interrupts training (not so fast it averages out). The
/// `avail_frac` axis splits this cycle per cell.
const CYCLE_SECS: f64 = 3600.0;

fn main() -> Result<()> {
    benchkit::banner(
        "fig10_availability_sweep",
        "participation under churn (TimelyFL advantage widens as availability drops)",
    );
    let bench = Bench::new()?;

    let mut base = scenario::resolve("cifar")?.config()?;
    base.rounds = bench.scale.rounds(60);
    base.eval_every = 20;
    // Pin the Markov cycle the avail_frac axis splits (kind stays always-on
    // until a cell sets avail_frac < 1.0 — the bit-compatible control).
    base.availability.mean_online_secs = CYCLE_SECS / 2.0;
    base.availability.mean_offline_secs = CYCLE_SECS / 2.0;
    let grid = SweepGrid::new(base)
        .axis("avail_frac", FRACTIONS)
        .strategy_axis_all();
    eprintln!(
        "  {} cells ({} fractions x full strategy registry) x {SEEDS} seeds ...",
        grid.len(),
        FRACTIONS.len()
    );
    let result = bench.runner().seeds(SEEDS).run(&grid)?;
    let n_strategies = grid.len() / FRACTIONS.len();

    let mut t = Table::new(&[
        "online_target",
        "strategy",
        "mean_particip",
        "online_frac",
        "avail_drops",
        "deadline_drops",
        "rounds",
    ]);
    let mut csv = String::from(
        "online_target,strategy,seeds,mean_participation,participation_std,\
         online_fraction,avail_drops,deadline_drops\n",
    );
    let mut gaps: Vec<(f64, f64, f64)> = Vec::new(); // (fraction, abs gap, rel gap %)

    for (fi, &frac) in FRACTIONS.iter().enumerate() {
        let cells = &result.cells[fi * n_strategies..(fi + 1) * n_strategies];
        for c in cells {
            let strategy = c.cell.cfg.strategy.as_str();
            let s = &c.summary;
            t.row(vec![
                format!("{frac:.1}"),
                strategy.to_string(),
                s.mean_participation.fmt(3),
                s.mean_online_fraction.fmt(3),
                s.avail_drops.fmt(1),
                s.deadline_drops.fmt(1),
                s.rounds.fmt(1),
            ]);
            csv.push_str(&format!(
                "{frac},{strategy},{SEEDS},{:.4},{:.4},{:.4},{:.1},{:.1}\n",
                s.mean_participation.mean,
                s.mean_participation.std,
                s.mean_online_fraction.mean,
                s.avail_drops.mean,
                s.deadline_drops.mean,
            ));
        }
        let by_name = |name: &str| {
            cells
                .iter()
                .find(|c| c.cell.cfg.strategy == name)
                .map(|c| c.summary.mean_participation.mean)
                .expect("registry strategy missing from cells")
        };
        let timely = by_name("TimelyFL");
        let fedbuff = by_name("FedBuff");
        let rel = (timely - fedbuff) / fedbuff.max(1e-9) * 100.0;
        gaps.push((frac, timely - fedbuff, rel));
    }

    let rendered = t.render();
    println!("{rendered}");

    println!("TimelyFL - FedBuff participation gap by availability:");
    for (frac, abs, rel) in &gaps {
        println!("  online~{:>3.0}%: +{abs:.3} absolute ({rel:+.1}% relative)", frac * 100.0);
    }
    println!(
        "expected shape: the relative gap GROWS as availability drops \
         (paper reference at full availability: +21.1%)."
    );

    let mut summary = rendered;
    for (frac, abs, rel) in &gaps {
        summary.push_str(&format!("gap@{frac:.1}={abs:.4} ({rel:+.1}%)\n"));
    }
    benchkit::write_result("fig10_availability_sweep.txt", &summary);
    benchkit::write_result("fig10_availability_sweep.csv", &csv);
    Ok(())
}
