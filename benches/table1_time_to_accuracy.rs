//! Table 1 — wall-clock training time to reach target validation accuracy
//! (CIFAR-10 / Google Speech / Reddit x FedAvg / FedOpt x TimelyFL /
//! FedBuff / SyncFL).
//!
//! Our substrate is the synthetic-workload simulator (DESIGN.md §3), so
//! absolute hours and absolute accuracies differ from the paper; the
//! reproduction target is the *shape*: TimelyFL reaches each target first,
//! FedBuff needs ~1.3-3x longer, SyncFL ~2.5-14x longer (and the hardest
//! targets are out of reach for the baselines within budget, like the
//! paper's "> 200 hr" cells).
//!
//! Hours reported are SIMULATED device time (the paper's own emulation
//! methodology); each run also logs real wall seconds for §Perf
//! accounting. Every cell is replicated over [`SEEDS`] seeds by the
//! experiment runner and every hour cell reports mean ± std (with how
//! many seeds reached the target).

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::experiment::{scenario, MeanStd, SweepGrid, TargetStat};
use timelyfl::metrics::report::Table;
use timelyfl::metrics::RunReport;

/// Seed replicates per (dataset, aggregator, strategy) cell.
const SEEDS: usize = 3;

struct Case {
    label: &'static str,
    /// Scenario name (the paper presets are registered scenario aliases).
    preset: &'static str,
    /// (display, value) pairs — two target rows like the paper.
    targets: [(&'static str, f64); 2],
    /// Round budget (full scale); the run stops early once the harder
    /// target is reached.
    rounds: usize,
    higher_better: bool,
}

const CASES: &[Case] = &[
    Case {
        label: "CIFAR-10 (vision)",
        preset: "cifar_fedavg",
        targets: [("40%", 0.40), ("50%", 0.50)],
        rounds: 220,
        higher_better: true,
    },
    Case {
        label: "CIFAR-10 (vision)",
        preset: "cifar_fedopt",
        targets: [("40%", 0.40), ("50%", 0.50)],
        rounds: 220,
        higher_better: true,
    },
    Case {
        label: "GoogleSpeech (speech)",
        preset: "speech_fedavg",
        targets: [("50%", 0.50), ("65%", 0.65)],
        rounds: 150,
        higher_better: true,
    },
    Case {
        label: "GoogleSpeech (speech)",
        preset: "speech_fedopt",
        targets: [("50%", 0.50), ("65%", 0.65)],
        rounds: 150,
        higher_better: true,
    },
    Case {
        label: "Reddit (text, ppl)",
        preset: "reddit_fedavg",
        targets: [("ppl 20", 20.0), ("ppl 12", 12.0)],
        rounds: 100,
        higher_better: false,
    },
    Case {
        label: "Reddit (text, ppl)",
        preset: "reddit_fedopt",
        targets: [("ppl 20", 20.0), ("ppl 12", 12.0)],
        rounds: 100,
        higher_better: false,
    },
];

/// The paper's Table 1 column layout (registry names, fixed order).
const STRATEGIES: [&str; 3] = ["TimelyFL", "FedBuff", "SyncFL"];

/// One case = a scenario-based grid over the Table 1 strategy columns,
/// replicated over [`SEEDS`] seeds, all cells run in parallel by the
/// experiment runner. Returns each strategy's per-seed reports, strategy
/// order.
fn run_case(bench: &Bench, case: &Case) -> Result<Vec<Vec<RunReport>>> {
    let mut base = scenario::resolve(case.preset)?.config()?;
    base.rounds = bench.scale.rounds(case.rounds);
    // SyncFL pays the straggler tax in *simulated* time, not wall time, so
    // the same round budget is fair across strategies.
    base.eval_every = 10;
    base.target_metric = Some(case.targets[1].1); // stop at the harder target
    eprintln!(
        "  {} / {} / {} (rounds<={}, {SEEDS} seeds) ...",
        case.label,
        case.preset.rsplit('_').next().unwrap(),
        STRATEGIES.join("/"),
        base.rounds
    );
    let grid = SweepGrid::new(base).axis("strategy", &STRATEGIES);
    let result = bench.runner().seeds(SEEDS).run(&grid)?;
    Ok(result.cells.into_iter().map(|c| c.reports).collect())
}

/// `"5.50±0.21 hr (3/3)"` or `"> budget"`.
fn fmt_target(t: &TargetStat) -> String {
    match &t.hours {
        Some(h) => format!("{} hr ({}/{SEEDS})", h.fmt(2), t.reached),
        None => "> budget".into(),
    }
}

/// `"(1.43x)"` mean-hours speedup annotation relative to a baseline cell.
fn fmt_speedup(t: &TargetStat, baseline: &TargetStat) -> String {
    match t.ratio_vs(baseline) {
        Some(x) => format!("({x:.2}x)"),
        None => "(—)".into(),
    }
}

fn csv_hours(t: &TargetStat) -> String {
    t.hours.as_ref().map_or_else(|| ">budget".into(), |h| format!("{:.3}", h.mean))
}

fn csv_std(t: &TargetStat) -> String {
    t.hours.as_ref().map_or_else(String::new, |h| format!("{:.3}", h.std))
}

fn main() -> Result<()> {
    benchkit::banner(
        "table1_time_to_accuracy",
        "Table 1 (time-to-target, 3 datasets x FedAvg/FedOpt x 3 strategies, mean±std over seeds)",
    );
    let bench = Bench::new()?;
    let mut out = Table::new(&[
        "dataset",
        "agg",
        "target",
        "TimelyFL",
        "FedBuff",
        "SyncFL",
        "best T/F/S",
    ]);
    let mut csv = String::from(
        "dataset,agg,target,seeds,timelyfl_hr,timelyfl_std,fedbuff_hr,fedbuff_std,\
         syncfl_hr,syncfl_std,fedbuff_x,syncfl_x\n",
    );

    for case in CASES {
        let agg = case.preset.rsplit('_').next().unwrap();
        let per_strategy: Vec<Vec<RunReport>> = run_case(&bench, case)?;

        for (tname, tval) in case.targets {
            let cells: Vec<TargetStat> = per_strategy
                .iter()
                .map(|reports| TargetStat::of(reports, tval, case.higher_better))
                .collect();
            let best = |reports: &[RunReport]| {
                let xs: Vec<f64> = reports
                    .iter()
                    .filter_map(|r| r.best_metric(case.higher_better))
                    .collect();
                if xs.is_empty() {
                    String::new()
                } else {
                    format!("{:.3}", MeanStd::of(&xs).mean)
                }
            };
            out.row(vec![
                case.label.into(),
                agg.into(),
                tname.into(),
                fmt_target(&cells[0]),
                format!("{} {}", fmt_target(&cells[1]), fmt_speedup(&cells[1], &cells[0])),
                format!("{} {}", fmt_target(&cells[2]), fmt_speedup(&cells[2], &cells[0])),
                per_strategy.iter().map(|r| best(r)).collect::<Vec<_>>().join("/"),
            ]);
            let x = |c: &TargetStat| {
                c.ratio_vs(&cells[0]).map_or_else(String::new, |x| format!("{x:.2}"))
            };
            csv.push_str(&format!(
                "{},{},{},{SEEDS},{},{},{},{},{},{},{},{}\n",
                case.label,
                agg,
                tname,
                csv_hours(&cells[0]),
                csv_std(&cells[0]),
                csv_hours(&cells[1]),
                csv_std(&cells[1]),
                csv_hours(&cells[2]),
                csv_std(&cells[2]),
                x(&cells[1]),
                x(&cells[2]),
            ));
        }
    }

    let rendered = out.render();
    println!("{rendered}");
    println!(
        "paper shape: FedBuff needs 1.28-2.89x TimelyFL's time, SyncFL 2.44-13.96x;\n\
         hardest targets unreachable for baselines within budget (paper: \"> 200 hr\")."
    );
    benchkit::write_result("table1_time_to_accuracy.txt", &rendered);
    benchkit::write_result("table1_time_to_accuracy.csv", &csv);
    Ok(())
}
