//! Table 1 — wall-clock training time to reach target validation accuracy
//! (CIFAR-10 / Google Speech / Reddit x FedAvg / FedOpt x TimelyFL /
//! FedBuff / SyncFL).
//!
//! Our substrate is the synthetic-workload simulator (DESIGN.md §3), so
//! absolute hours and absolute accuracies differ from the paper; the
//! reproduction target is the *shape*: TimelyFL reaches each target first,
//! FedBuff needs ~1.3-3x longer, SyncFL ~2.5-14x longer (and the hardest
//! targets are out of reach for the baselines within budget, like the
//! paper's "> 200 hr" cells).
//!
//! Hours reported are SIMULATED device time (the paper's own emulation
//! methodology); each run also logs real wall seconds for §Perf accounting.

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::experiment::{scenario, SweepGrid};
use timelyfl::metrics::report::{fmt_hours, fmt_speedup, Table};
use timelyfl::metrics::RunReport;

struct Case {
    label: &'static str,
    /// Scenario name (the paper presets are registered scenario aliases).
    preset: &'static str,
    /// (display, value) pairs — two target rows like the paper.
    targets: [(&'static str, f64); 2],
    /// Round budget (full scale); the run stops early once the harder
    /// target is reached.
    rounds: usize,
    higher_better: bool,
}

const CASES: &[Case] = &[
    Case {
        label: "CIFAR-10 (vision)",
        preset: "cifar_fedavg",
        targets: [("40%", 0.40), ("50%", 0.50)],
        rounds: 220,
        higher_better: true,
    },
    Case {
        label: "CIFAR-10 (vision)",
        preset: "cifar_fedopt",
        targets: [("40%", 0.40), ("50%", 0.50)],
        rounds: 220,
        higher_better: true,
    },
    Case {
        label: "GoogleSpeech (speech)",
        preset: "speech_fedavg",
        targets: [("50%", 0.50), ("65%", 0.65)],
        rounds: 150,
        higher_better: true,
    },
    Case {
        label: "GoogleSpeech (speech)",
        preset: "speech_fedopt",
        targets: [("50%", 0.50), ("65%", 0.65)],
        rounds: 150,
        higher_better: true,
    },
    Case {
        label: "Reddit (text, ppl)",
        preset: "reddit_fedavg",
        targets: [("ppl 20", 20.0), ("ppl 12", 12.0)],
        rounds: 100,
        higher_better: false,
    },
    Case {
        label: "Reddit (text, ppl)",
        preset: "reddit_fedopt",
        targets: [("ppl 20", 20.0), ("ppl 12", 12.0)],
        rounds: 100,
        higher_better: false,
    },
];

/// The paper's Table 1 column layout (registry names, fixed order).
const STRATEGIES: [&str; 3] = ["TimelyFL", "FedBuff", "SyncFL"];

/// One case = a scenario-based grid over the Table 1 strategy columns, all
/// cells run in parallel by the experiment runner.
fn run_case(bench: &Bench, case: &Case) -> Result<Vec<RunReport>> {
    let mut base = scenario::resolve(case.preset)?.config()?;
    base.rounds = bench.scale.rounds(case.rounds);
    // SyncFL pays the straggler tax in *simulated* time, not wall time, so
    // the same round budget is fair across strategies.
    base.eval_every = 10;
    base.target_metric = Some(case.targets[1].1); // stop at the harder target
    eprintln!(
        "  {} / {} / {} (rounds<={}) ...",
        case.label,
        case.preset.rsplit('_').next().unwrap(),
        STRATEGIES.join("/"),
        base.rounds
    );
    let grid = SweepGrid::new(base).axis("strategy", &STRATEGIES);
    Ok(bench.runner().run(&grid)?.into_first_reports())
}

fn main() -> Result<()> {
    benchkit::banner(
        "table1_time_to_accuracy",
        "Table 1 (time-to-target, 3 datasets x FedAvg/FedOpt x 3 strategies)",
    );
    let bench = Bench::new()?;
    let mut out = Table::new(&[
        "dataset",
        "agg",
        "target",
        "TimelyFL",
        "FedBuff",
        "SyncFL",
        "best T/F/S",
    ]);
    let mut csv = String::from(
        "dataset,agg,target,timelyfl_hr,fedbuff_hr,syncfl_hr,fedbuff_x,syncfl_x\n",
    );

    for case in CASES {
        let agg = case.preset.rsplit('_').next().unwrap();
        let reports: Vec<RunReport> = run_case(&bench, case)?;

        for (tname, tval) in case.targets {
            let times: Vec<Option<f64>> = reports
                .iter()
                .map(|r| r.time_to_target(tval, case.higher_better))
                .collect();
            out.row(vec![
                case.label.into(),
                agg.into(),
                tname.into(),
                fmt_hours(times[0]),
                format!("{} {}", fmt_hours(times[1]), fmt_speedup(times[0], times[1])),
                format!("{} {}", fmt_hours(times[2]), fmt_speedup(times[0], times[2])),
                reports
                    .iter()
                    .map(|r| {
                        r.best_metric(case.higher_better)
                            .map(|m| format!("{m:.3}"))
                            .unwrap_or_default()
                    })
                    .collect::<Vec<_>>()
                    .join("/"),
            ]);
            let h = |t: Option<f64>| t.map(|v| format!("{v:.3}")).unwrap_or_else(|| ">budget".into());
            let x = |t: Option<f64>| match (times[0], t) {
                (Some(a), Some(b)) if a > 0.0 => format!("{:.2}", b / a),
                _ => String::new(),
            };
            csv.push_str(&format!(
                "{},{},{},{},{},{},{},{}\n",
                case.label,
                agg,
                tname,
                h(times[0]),
                h(times[1]),
                h(times[2]),
                x(times[1]),
                x(times[2]),
            ));
        }
    }

    let rendered = out.render();
    println!("{rendered}");
    println!(
        "paper shape: FedBuff needs 1.28-2.89x TimelyFL's time, SyncFL 2.44-13.96x;\n\
         hardest targets unreachable for baselines within budget (paper: \"> 200 hr\")."
    );
    benchkit::write_result("table1_time_to_accuracy.txt", &rendered);
    benchkit::write_result("table1_time_to_accuracy.csv", &csv);
    Ok(())
}
