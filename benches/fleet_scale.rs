//! Fleet-scale bench — clients vs wall-time and peak RSS for the lazy,
//! indexed sim core against the historical eager core.
//!
//! The claim being measured: with `fleet_core = lazy` the per-round cost is
//! O(active + transitions·log n) instead of O(population), so wall-time
//! stays near-flat as the fleet grows from 10^3 to 10^6 clients while the
//! eager core degrades linearly. Training load is held CONSTANT across
//! scale points (same concurrency, same rounds, same tiny KWS model), so
//! any wall-time growth is sim-core overhead by construction.
//!
//! Output: an aligned table on stdout plus `results/BENCH_fleet.json`
//! recording the full curve (population, core, wall seconds, simulated
//! seconds, rounds, events, peak RSS) for EXPERIMENTS.md and CI trending.
//! Peak RSS is the process high-water mark (`VmHWM` from
//! `/proc/self/status`) sampled after each point — monotone by definition,
//! so the meaningful reading is the value at each population's FIRST
//! appearance in the run order (ascending, lazy before eager).

use std::time::Instant;

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::experiment::scenario;
use timelyfl::fleet::FleetCore;
use timelyfl::metrics::report::Table;
use timelyfl::util::json::Json;

/// Process peak-RSS high-water mark in kB (Linux; None elsewhere).
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("VmHWM:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

fn main() -> Result<()> {
    benchkit::banner(
        "fleet_scale",
        "fleet subsystem scaling (lazy vs eager sim core, 10^3..10^6 clients)",
    );
    let bench = Bench::new()?;

    // Ascending fleet sizes; the eager core is only run up to the cutoff
    // where its O(population)-per-round scans stay affordable — the last
    // point is exactly the regime the lazy core exists for.
    let populations: &[usize] = if bench.scale.fast {
        &[1_000, 10_000, 100_000]
    } else {
        &[1_000, 10_000, 100_000, 1_000_000]
    };
    let eager_cutoff = 100_000;
    let base = scenario::resolve("fleet_1m")?.config()?;

    let mut table = Table::new(&[
        "population",
        "core",
        "wall_secs",
        "sim_hours",
        "rounds",
        "events",
        "peak_rss_mb",
    ]);
    let mut points = Vec::new();

    for &population in populations {
        for core in [FleetCore::Lazy, FleetCore::Eager] {
            if core == FleetCore::Eager && population > eager_cutoff {
                eprintln!("  {population} / eager: skipped (cutoff {eager_cutoff})");
                continue;
            }
            let mut cfg = base.clone();
            cfg.population = population;
            // Constant training load across points: fixed concurrency and
            // round budget, so the x-axis varies ONLY the idle fleet.
            cfg.concurrency = 64;
            cfg.rounds = bench.scale.rounds(4).min(4);
            cfg.eval_every = cfg.rounds;
            cfg.fleet_core = core;
            eprintln!("  {population} / {} ...", core.name());
            let start = Instant::now();
            let report = bench.run(cfg)?;
            let wall = start.elapsed().as_secs_f64();
            let rss_kb = peak_rss_kb();
            table.row(vec![
                population.to_string(),
                core.name().into(),
                format!("{wall:.2}"),
                format!("{:.2}", report.sim_secs / 3600.0),
                report.total_rounds.to_string(),
                report.events_processed.to_string(),
                rss_kb.map_or("-".into(), |kb| format!("{:.1}", kb as f64 / 1024.0)),
            ]);
            points.push(Json::obj(vec![
                ("population", Json::num(population as f64)),
                ("core", Json::str(core.name())),
                ("wall_secs", Json::num(wall)),
                ("sim_secs", Json::num(report.sim_secs)),
                ("rounds", Json::num(report.total_rounds as f64)),
                ("events_processed", Json::num(report.events_processed as f64)),
                ("peak_rss_kb", rss_kb.map_or(Json::Null, |kb| Json::num(kb as f64))),
            ]));
        }
    }

    let rendered = table.render();
    println!("{rendered}");
    println!(
        "shape target: lazy wall-time near-flat in population at fixed concurrency;\n\
         eager grows with the idle fleet it keeps scanning."
    );
    let json = Json::obj(vec![
        ("bench", Json::str("fleet_scale")),
        ("scenario", Json::str("fleet_1m")),
        ("concurrency", Json::num(64.0)),
        ("points", Json::arr(points)),
    ]);
    benchkit::write_result("BENCH_fleet.json", &json.to_string());
    benchkit::write_result("fleet_scale.txt", &rendered);
    Ok(())
}
