//! Scheduling-weights bench — what aggregation weighting buys (and costs)
//! under correlated regional churn.
//!
//! Sweeps the weigher registry (`uniform`, `staleness`, `sched-joint`)
//! across every registered strategy on the `cifar_regional` scenario, 3
//! seeds per cell. Two observables per cell:
//!
//! - **participation Gini** — dispersion of per-client participation
//!   rates. Weighers don't change who trains (clocks, cohorts, and the
//!   drop ledger are weigher-invariant, locked by
//!   `tests/weigher_equivalence.rs`), so the Gini columns must agree
//!   across weighers row-for-row — a built-in cross-check that the
//!   learning-curve deltas below come from the weights alone.
//! - **time-to-accuracy** — simulated hours to the target metric. This is
//!   where non-uniform weighers may move the needle: discounting stale or
//!   churn-prone contributions changes the aggregated delta and nothing
//!   else.
//!
//! The same study is one CLI line:
//! `timelyfl sweep --scenario cifar_regional --axis weigher=uniform,staleness,sched-joint --seeds 3`.
//!
//! Output: an aligned table on stdout plus `results/BENCH_scheduling.json`
//! (schema in `results/README.md`) with one point per (weigher, strategy).

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::experiment::{scenario, MeanStd, SweepGrid};
use timelyfl::metrics::report::Table;
use timelyfl::scheduling;
use timelyfl::util::json::Json;

/// Seed replicates per (weigher, strategy) cell.
const SEEDS: usize = 3;

/// Time-to-accuracy target — modest on purpose: the scaled-down bench
/// fleet must be able to reach it within its round budget on at least some
/// seeds, or every cell degenerates to "> budget".
const TARGET_METRIC: f64 = 0.2;

fn main() -> Result<()> {
    benchkit::banner(
        "scheduling_weights",
        "aggregation weighers (uniform / staleness / sched-joint) under correlated regional churn",
    );
    let bench = Bench::new()?;

    let mut base = scenario::resolve("cifar_regional")?.config()?;
    base.rounds = bench.scale.rounds(40);
    base.eval_every = 10;
    base.target_metric = Some(TARGET_METRIC);
    let weighers = scheduling::names();
    let grid = SweepGrid::new(base)
        .axis("weigher", &weighers)
        .strategy_axis_all();
    let n_strategies = grid.len() / weighers.len();
    eprintln!(
        "  {} cells ({} weighers x full strategy registry) x {SEEDS} seeds ...",
        grid.len(),
        weighers.len()
    );
    let result = bench.runner().seeds(SEEDS).run(&grid)?;

    let mut t = Table::new(&[
        "weigher",
        "strategy",
        "particip_gini",
        "mean_particip",
        "final_metric",
        "tt_acc_hours",
        "reached",
        "rounds",
    ]);
    let mut points = Vec::new();
    // (weigher, strategy) -> (gini MeanStd, time-to-target) for the deltas.
    let mut stats: Vec<(String, String, MeanStd, Option<MeanStd>)> = Vec::new();

    for (wi, weigher) in weighers.iter().enumerate() {
        let cells = &result.cells[wi * n_strategies..(wi + 1) * n_strategies];
        for c in cells {
            let strategy = c.cell.cfg.strategy.clone();
            let s = &c.summary;
            let ginis: Vec<f64> =
                c.reports.iter().map(|r| r.participation_gini()).collect();
            let gini = MeanStd::of(&ginis);
            let tt = s.time_to_target.as_ref().expect("target_metric set on base");
            t.row(vec![
                weigher.to_string(),
                strategy.clone(),
                gini.fmt(3),
                s.mean_participation.fmt(3),
                s.final_metric.map_or("-".into(), |m| m.fmt(4)),
                tt.hours.map_or("> budget".into(), |h| h.fmt(2)),
                format!("{}/{SEEDS}", tt.reached),
                s.rounds.fmt(1),
            ]);
            points.push(Json::obj(vec![
                ("weigher", Json::str(weigher.to_string())),
                ("strategy", Json::str(strategy.clone())),
                ("seeds", Json::num(SEEDS as f64)),
                ("participation_gini", Json::num(gini.mean)),
                ("participation_gini_std", Json::num(gini.std)),
                ("mean_participation", Json::num(s.mean_participation.mean)),
                (
                    "final_metric",
                    s.final_metric.map_or(Json::Null, |m| Json::num(m.mean)),
                ),
                ("target_metric", Json::num(TARGET_METRIC)),
                ("target_reached", Json::num(tt.reached as f64)),
                (
                    "hours_to_target",
                    tt.hours.map_or(Json::Null, |h| Json::num(h.mean)),
                ),
                (
                    "hours_to_target_std",
                    tt.hours.map_or(Json::Null, |h| Json::num(h.std)),
                ),
                ("avail_drops", Json::num(s.avail_drops.mean)),
                ("deadline_drops", Json::num(s.deadline_drops.mean)),
                ("rounds", Json::num(s.rounds.mean)),
                ("sim_hours", Json::num(s.sim_hours.mean)),
            ]));
            stats.push((weigher.to_string(), strategy, gini, tt.hours));
        }
    }

    let rendered = t.render();
    println!("{rendered}");

    // Per-strategy deltas vs the uniform anchor — and the invariance check.
    let lookup = |weigher: &str, strategy: &str| {
        stats
            .iter()
            .find(|(w, st, _, _)| w == weigher && st == strategy)
            .map(|(_, _, g, h)| (*g, *h))
            .expect("cell missing from stats")
    };
    let mut summary = rendered;
    println!("vs uniform, per strategy (Gini MUST be identical; hours may move):");
    for c in &result.cells[..n_strategies] {
        let strategy = c.cell.cfg.strategy.as_str();
        let (gu, hu) = lookup("uniform", strategy);
        for weigher in ["staleness", "sched-joint"] {
            let (gw, hw) = lookup(weigher, strategy);
            assert_eq!(
                gu.mean, gw.mean,
                "{strategy} + {weigher}: participation Gini moved — weighers must \
                 not touch cohorts (see tests/weigher_equivalence.rs)"
            );
            let delta = match (hu, hw) {
                (Some(a), Some(b)) => format!("{:+.2} hr ({:.2} -> {:.2})", b.mean - a.mean, a.mean, b.mean),
                _ => "n/a (target not reached on both sides)".into(),
            };
            let line = format!("  {strategy:>9} / {weigher:<11}: time-to-accuracy {delta}");
            println!("{line}");
            summary.push_str(&line);
            summary.push('\n');
        }
    }
    println!(
        "expected shape: Gini columns agree across weighers row-for-row (weights touch\n\
         only the aggregated delta); staleness/sched-joint may trade time-to-accuracy\n\
         against stale-update noise on the async strategies."
    );

    let json = Json::obj(vec![
        ("bench", Json::str("scheduling_weights")),
        ("scenario", Json::str("cifar_regional")),
        ("seeds", Json::num(SEEDS as f64)),
        ("target_metric", Json::num(TARGET_METRIC)),
        ("points", Json::arr(points)),
    ]);
    benchkit::write_result("BENCH_scheduling.json", &json.to_string());
    benchkit::write_result("scheduling_weights.txt", &summary);
    Ok(())
}
