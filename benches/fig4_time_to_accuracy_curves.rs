//! Figures 1c + 4 — time-to-accuracy learning curves for SyncFL, FedBuff
//! and TimelyFL on all three workloads.
//!
//! Emits one CSV per (dataset, strategy) under `results/` with the
//! (sim_hours, metric) series, and prints a coarse text plot per dataset.
//! Paper shape: TimelyFL's curve dominates FedBuff's, which dominates
//! SyncFL's over simulated time; FedBuff converges fast early but plateaus
//! lower (Fig. 1c).
//!
//! One scenario + strategy-axis grid per dataset, cells run in parallel by
//! `ExperimentRunner`.

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::experiment::{scenario, SweepGrid};
use timelyfl::metrics::RunReport;

/// Fig. 1c/4 curve set (registry names; first letters label the plot).
const STRATEGIES: [&str; 3] = ["TimelyFL", "FedBuff", "SyncFL"];

/// Coarse terminal plot: metric vs sim-hours, one letter per strategy.
fn text_plot(reports: &[RunReport], higher_better: bool) -> String {
    const W: usize = 72;
    const H: usize = 16;
    let mut grid = vec![vec![' '; W + 1]; H + 1];
    let max_h = reports
        .iter()
        .flat_map(|r| r.eval_points.iter().map(|p| p.sim_secs / 3600.0))
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let (lo, hi) = reports
        .iter()
        .flat_map(|r| r.eval_points.iter().map(|p| p.metric))
        .fold((f64::MAX, f64::MIN), |(lo, hi), m| (lo.min(m), hi.max(m)));
    let span = (hi - lo).max(1e-9);
    for r in reports {
        let ch = r.strategy.chars().next().unwrap(); // T / F / S
        for p in &r.eval_points {
            let x = ((p.sim_secs / 3600.0) / max_h * W as f64).round() as usize;
            let ynorm = (p.metric - lo) / span;
            let y = if higher_better { 1.0 - ynorm } else { ynorm };
            let y = (y * H as f64).round() as usize;
            grid[y.min(H)][x.min(W)] = ch;
        }
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{hi:8.3} ")
        } else if i == H {
            format!("{lo:8.3} ")
        } else {
            " ".repeat(9)
        };
        out.push_str(&label);
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{:>9}0{:>width$.1} sim hours\n", "", max_h, width = W));
    out
}

fn main() -> Result<()> {
    benchkit::banner(
        "fig4_time_to_accuracy_curves",
        "Figs. 1c + 4 (learning curves over simulated time, 3 datasets)",
    );
    let bench = Bench::new()?;

    for (label, scenario_name, rounds, higher_better) in [
        ("cifar10", "cifar_fedopt", 180, true),
        ("google_speech", "speech_fedopt", 120, true),
        ("reddit", "reddit_fedopt", 80, false),
    ] {
        println!("--- {label} ({scenario_name}) ---");
        let mut base = scenario::resolve(scenario_name)?.config()?;
        base.rounds = bench.scale.rounds(rounds);
        base.eval_every = 10;
        eprintln!("  {} (rounds={}) ...", STRATEGIES.join("/"), base.rounds);
        let grid = SweepGrid::new(base).axis("strategy", &STRATEGIES);
        let reports: Vec<RunReport> = bench.runner().run(&grid)?.into_first_reports();
        for report in &reports {
            benchkit::write_result(
                &format!("fig4_curve_{label}_{}.csv", report.strategy.to_lowercase()),
                &report.curve_csv(),
            );
        }
        print!("{}", text_plot(&reports, higher_better));
        println!("  (T = TimelyFL, F = FedBuff, S = SyncFL)\n");
        for r in &reports {
            println!(
                "  {:9} final={:.3} best={:.3} rounds={} sim_h={:.2}",
                r.strategy,
                r.final_metric().unwrap_or(f64::NAN),
                r.best_metric(higher_better).unwrap_or(f64::NAN),
                r.total_rounds,
                r.sim_secs / 3600.0
            );
        }
        println!();
    }
    println!("paper shape: TimelyFL dominates; FedBuff fast early, plateaus below.");
    Ok(())
}
