//! Figure 7 — adaptive vs non-adaptive workload scheduling (ablation).
//!
//! Paper: with adaptive per-round re-scheduling, TimelyFL reaches 50%
//! accuracy 4.09x faster and ends 10.89% higher than a variant whose
//! workload assignment is frozen after the first round (concurrency 64).
//! `cfg.adaptive = false` reproduces exactly that ablation: T_k and every
//! client's (E, alpha) stay at their round-0 values while device
//! conditions keep drifting (Eq. 2 disturbance + per-round bandwidth).

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::experiment::{scenario, SweepGrid};
use timelyfl::metrics::report::{fmt_hours, fmt_speedup, Table};

const TARGET: f64 = 0.40;

fn main() -> Result<()> {
    benchkit::banner(
        "fig7_adaptive_ablation",
        "Fig. 7 (adaptive workload scheduling ablation: 4.09x to-target, +10.9% final)",
    );
    let bench = Bench::new()?;

    // The ablation is one boolean axis on the cifar scenario.
    let mut base = scenario::resolve("cifar")?.config()?;
    base.concurrency = 32; // paper uses 64 of 128; we scale 32 of 64
    base.rounds = bench.scale.rounds(180);
    base.eval_every = 10;
    eprintln!("  adaptive=true/false (rounds={}) ...", base.rounds);
    let grid = SweepGrid::new(base).axis("adaptive", &["true", "false"]);
    let result = bench.runner().run(&grid)?;
    // Guard the label <-> cell binding against future axis reordering.
    for (cell, want) in result.cells.iter().zip([true, false]) {
        assert_eq!(cell.cell.cfg.adaptive, want, "grid order drifted");
    }
    let reports: Vec<_> = result.into_first_reports();
    for (r, name) in reports.iter().zip(["adaptive", "frozen"]) {
        benchkit::write_result(&format!("fig7_curve_{name}.csv"), &r.curve_csv());
    }
    let [adaptive, frozen] = &reports[..] else { unreachable!() };

    let ta = adaptive.time_to_target(TARGET, true);
    let tf = frozen.time_to_target(TARGET, true);
    let fa = adaptive.best_metric(true).unwrap_or(0.0);
    let ff = frozen.best_metric(true).unwrap_or(0.0);

    let mut t = Table::new(&[
        "schedule",
        "time to 40%",
        "final acc",
        "mean participation",
        "rounds",
    ]);
    t.row(vec![
        "adaptive (TimelyFL)".into(),
        fmt_hours(ta),
        format!("{fa:.3}"),
        format!("{:.3}", adaptive.mean_participation()),
        adaptive.total_rounds.to_string(),
    ]);
    t.row(vec![
        "frozen after round 0".into(),
        format!("{} {}", fmt_hours(tf), fmt_speedup(ta, tf)),
        format!("{ff:.3} ({:+.3})", ff - fa),
        format!("{:.3}", frozen.mean_participation()),
        frozen.total_rounds.to_string(),
    ]);
    let rendered = t.render();
    println!("{rendered}");
    println!("paper shape: adaptive is ~4x faster to target and ~0.11 higher at the end.");
    benchkit::write_result("fig7_adaptive_ablation.txt", &rendered);
    Ok(())
}
