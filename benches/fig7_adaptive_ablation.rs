//! Figure 7 — adaptive vs non-adaptive workload scheduling (ablation).
//!
//! Paper: with adaptive per-round re-scheduling, TimelyFL reaches 50%
//! accuracy 4.09x faster and ends 10.89% higher than a variant whose
//! workload assignment is frozen after the first round (concurrency 64).
//! `cfg.adaptive = false` reproduces exactly that ablation: T_k and every
//! client's (E, alpha) stay at their round-0 values while device
//! conditions keep drifting (Eq. 2 disturbance + per-round bandwidth).

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::config::RunConfig;
use timelyfl::metrics::report::{fmt_hours, fmt_speedup, Table};

const TARGET: f64 = 0.40;

fn main() -> Result<()> {
    benchkit::banner(
        "fig7_adaptive_ablation",
        "Fig. 7 (adaptive workload scheduling ablation: 4.09x to-target, +10.9% final)",
    );
    let bench = Bench::new()?;

    let mut reports = Vec::new();
    for adaptive in [true, false] {
        let mut cfg = RunConfig::preset("cifar_fedavg")?;
        cfg.adaptive = adaptive;
        cfg.concurrency = 32; // paper uses 64 of 128; we scale 32 of 64
        cfg.rounds = bench.scale.rounds(180);
        cfg.eval_every = 10;
        eprintln!("  adaptive={adaptive} (rounds={}) ...", cfg.rounds);
        let r = bench.run(cfg)?;
        benchkit::write_result(
            &format!(
                "fig7_curve_{}.csv",
                if adaptive { "adaptive" } else { "frozen" }
            ),
            &r.curve_csv(),
        );
        reports.push(r);
    }
    let [adaptive, frozen] = &reports[..] else { unreachable!() };

    let ta = adaptive.time_to_target(TARGET, true);
    let tf = frozen.time_to_target(TARGET, true);
    let fa = adaptive.best_metric(true).unwrap_or(0.0);
    let ff = frozen.best_metric(true).unwrap_or(0.0);

    let mut t = Table::new(&[
        "schedule",
        "time to 40%",
        "final acc",
        "mean participation",
        "rounds",
    ]);
    t.row(vec![
        "adaptive (TimelyFL)".into(),
        fmt_hours(ta),
        format!("{fa:.3}"),
        format!("{:.3}", adaptive.mean_participation()),
        adaptive.total_rounds.to_string(),
    ]);
    t.row(vec![
        "frozen after round 0".into(),
        format!("{} {}", fmt_hours(tf), fmt_speedup(ta, tf)),
        format!("{ff:.3} ({:+.3})", ff - fa),
        format!("{:.3}", frozen.mean_participation()),
        frozen.total_rounds.to_string(),
    ]);
    let rendered = t.render();
    println!("{rendered}");
    println!("paper shape: adaptive is ~4x faster to target and ~0.11 higher at the end.");
    benchkit::write_result("fig7_adaptive_ablation.txt", &rendered);
    Ok(())
}
