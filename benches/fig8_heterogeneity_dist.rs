//! Figure 8 — heterogeneous system utility across simulated clients.
//!
//! Paper (Appendix A.1.2): AI-Benchmark compute times span ~13.3x between
//! the slowest and fastest device (Fig. 8a); MobiPerf bandwidths span ~200x
//! (Fig. 8b). This bench generates the `fleet_hetero` scenario's
//! 1000-client fleet from our calibrated log-normal substitutes and prints
//! both distributions (histogram + percentiles) plus the max/min spread —
//! the paper's summary statistic. (No training runs — the one bench that
//! uses the scenario registry without the `ExperimentRunner`.)

use timelyfl::benchkit::{self, Scale};
use timelyfl::devices::Fleet;
use timelyfl::experiment::scenario;
use timelyfl::metrics::report::Table;
use timelyfl::util::rng::Rng;

fn percentile(sorted: &[f64], q: f64) -> f64 {
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

fn ascii_hist(values: &[f64], buckets: usize) -> String {
    // log-scaled buckets: both paper distributions are heavy-tailed
    let lo = values.iter().cloned().fold(f64::MAX, f64::min);
    let hi = values.iter().cloned().fold(f64::MIN, f64::max);
    let (alo, ahi) = (lo.ln(), hi.ln());
    let mut counts = vec![0usize; buckets];
    for &v in values {
        let t = ((v.ln() - alo) / (ahi - alo) * buckets as f64) as usize;
        counts[t.min(buckets - 1)] += 1;
    }
    let max = *counts.iter().max().unwrap();
    let mut out = String::new();
    for (i, &c) in counts.iter().enumerate() {
        let left = (alo + (ahi - alo) * i as f64 / buckets as f64).exp();
        let bar = "#".repeat((c as f64 / max as f64 * 48.0).round() as usize);
        out.push_str(&format!("{left:>10.2}  {bar} {c}\n"));
    }
    out
}

fn main() -> anyhow::Result<()> {
    benchkit::banner(
        "fig8_heterogeneity_dist",
        "Figure 8 (a: compute spread ~13.3x, b: bandwidth spread ~200x)",
    );
    let scale = Scale::from_env();
    // Fleet calibration + population come from the `fleet_hetero` scenario
    // (no training runs here — this is a pure distribution study).
    let cfg = scenario::resolve("fleet_hetero")?.config()?;
    let n = scale.iters(cfg.population);

    let mut rng = Rng::seed_from(0xF18);
    let fleet = Fleet::generate(n, cfg.fleet.clone(), &mut rng);

    // --- Fig. 8a analogue: per-client base compute time -------------------
    let mut cmp: Vec<f64> = fleet.devices.iter().map(|d| d.base_epoch_secs).collect();
    cmp.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cmp_spread = cmp.last().unwrap() / cmp.first().unwrap();

    println!("--- (a) compute: seconds per local epoch, {n} clients ---");
    print!("{}", ascii_hist(&cmp, 12));

    // --- Fig. 8b analogue: per-round bandwidth draws -----------------------
    let draws = scale.iters(5000);
    let mut bw: Vec<f64> = (0..draws)
        .map(|_| fleet.round_conditions(&mut rng).bandwidth / 1e6)
        .collect();
    bw.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let bw_spread = bw.last().unwrap() / bw.first().unwrap();

    println!("--- (b) bandwidth: MB/s per round draw, {draws} draws ---");
    print!("{}", ascii_hist(&bw, 12));

    let mut t = Table::new(&["distribution", "p1", "p50", "p99", "max/min", "paper max/min"]);
    t.row(vec![
        "compute (s/epoch)".into(),
        format!("{:.1}", percentile(&cmp, 0.01)),
        format!("{:.1}", percentile(&cmp, 0.50)),
        format!("{:.1}", percentile(&cmp, 0.99)),
        format!("{cmp_spread:.1}x"),
        "~13.3x".into(),
    ]);
    t.row(vec![
        "bandwidth (MB/s)".into(),
        format!("{:.3}", percentile(&bw, 0.01)),
        format!("{:.3}", percentile(&bw, 0.50)),
        format!("{:.3}", percentile(&bw, 0.99)),
        format!("{bw_spread:.0}x"),
        "~200x".into(),
    ]);
    let rendered = t.render();
    println!("{rendered}");
    benchkit::write_result("fig8_heterogeneity.txt", &rendered);

    anyhow::ensure!(cmp_spread <= 13.3 + 1e-6, "compute spread blew past calibration");
    anyhow::ensure!(bw_spread <= 200.0 + 1e-6, "bandwidth spread blew past calibration");
    Ok(())
}
