//! Deferred-vs-eager dispatch execution under heavy churn: how much real
//! accelerator work does deferral skip, and what does that buy in wall
//! time?
//!
//! TimelyFL's setting is a heavily-churned population (intermittently
//! available clients, §1); Papaya reports that at production scale the
//! dominant cost under churn is exactly the in-flight work a cancelled
//! dispatch wastes. `SimEngine::dispatch` now defers the PJRT executions
//! to the generation-validated finish event, so a churn-cancelled dispatch
//! costs nothing on the accelerator; `--eager-train` (here
//! `cfg.eager_train`) restores the historical train-at-dispatch behaviour
//! for the A/B.
//!
//! Every registered strategy runs the same churn-heavy Markov scenario in
//! both modes at a fixed seed. Per (strategy, mode) row: dispatches,
//! executions, avoided count + ratio, real PJRT train steps, and wall
//! seconds; per strategy a delta line with the avoided ratio and wall-time
//! saving. Round-stepped strategies are the control — they train
//! synchronously, so both modes must coincide (avoided = 0).

use anyhow::Result;
use timelyfl::availability::AvailabilityKind;
use timelyfl::benchkit::{self, Bench};
use timelyfl::config::RunConfig;
use timelyfl::coordinator::registry;
use timelyfl::metrics::report::Table;
use timelyfl::metrics::RunReport;

/// Mean online/offline dwell seconds: ~1/3 steady-state availability with
/// dwells comparable to round times, so mid-training churn-outs are the
/// common case (the regime SEAFL's selective training targets).
const MEAN_ONLINE_SECS: f64 = 400.0;
const MEAN_OFFLINE_SECS: f64 = 800.0;

fn churn_cfg(strategy: &str, rounds: usize, eager: bool) -> Result<RunConfig> {
    let mut cfg = RunConfig::preset("cifar_fedavg")?;
    cfg.strategy = strategy.to_string();
    cfg.rounds = rounds;
    cfg.eval_every = 20;
    cfg.eager_train = eager;
    cfg.availability.kind = AvailabilityKind::Markov;
    cfg.availability.mean_online_secs = MEAN_ONLINE_SECS;
    cfg.availability.mean_offline_secs = MEAN_OFFLINE_SECS;
    Ok(cfg)
}

fn main() -> Result<()> {
    benchkit::banner(
        "deferred_wasted_work",
        "PJRT executions skipped by deferred dispatch under heavy churn (eager A/B)",
    );
    let bench = Bench::new()?;
    let rounds = bench.scale.rounds(40);

    let mut t = Table::new(&[
        "strategy",
        "mode",
        "dispatches",
        "executed",
        "avoided",
        "avoided_ratio",
        "pjrt_steps",
        "wall_secs",
    ]);
    let mut csv = String::from(
        "strategy,mode,dispatches,executed,avoided,avoided_ratio,pjrt_steps,wall_secs\n",
    );
    let mut deltas: Vec<String> = Vec::new();

    for info in registry::STRATEGIES {
        let mut by_mode: Vec<RunReport> = Vec::new();
        for eager in [true, false] {
            let mode = if eager { "eager" } else { "deferred" };
            eprintln!("  {} ({mode}, rounds={rounds}) ...", info.name);
            let r = bench.run(churn_cfg(info.name, rounds, eager)?)?;
            t.row(vec![
                r.strategy.clone(),
                mode.to_string(),
                r.total_train_dispatches().to_string(),
                r.trainings_executed.to_string(),
                r.trainings_avoided.to_string(),
                format!("{:.3}", r.trainings_avoided_ratio()),
                r.real_train_steps.to_string(),
                format!("{:.2}", r.wall_secs),
            ]);
            csv.push_str(&format!(
                "{},{mode},{},{},{},{:.4},{},{:.3}\n",
                r.strategy,
                r.total_train_dispatches(),
                r.trainings_executed,
                r.trainings_avoided,
                r.trainings_avoided_ratio(),
                r.real_train_steps,
                r.wall_secs,
            ));
            by_mode.push(r);
        }
        let (eager, deferred) = (&by_mode[0], &by_mode[1]); // [true, false] order above
        let steps_saved = eager.real_train_steps.saturating_sub(deferred.real_train_steps);
        let wall_delta = eager.wall_secs - deferred.wall_secs;
        deltas.push(format!(
            "{}: avoided {}/{} dispatches ({:.1}%), {} fewer PJRT steps, wall {:+.2}s ({:+.1}%)",
            info.name,
            deferred.trainings_avoided,
            deferred.total_train_dispatches(),
            deferred.trainings_avoided_ratio() * 100.0,
            steps_saved,
            wall_delta,
            wall_delta / eager.wall_secs.max(1e-9) * 100.0,
        ));
    }

    let rendered = t.render();
    println!("{rendered}");
    println!("deferred-vs-eager deltas (same seed, same schedule):");
    for d in &deltas {
        println!("  {d}");
    }
    println!(
        "expected shape: event-driven strategies (FedBuff, SemiAsync) avoid a \
         non-trivial dispatch fraction and strictly reduce PJRT steps + wall time; \
         round-stepped strategies coincide across modes (the control)."
    );

    let mut summary = rendered;
    for d in &deltas {
        summary.push_str(d);
        summary.push('\n');
    }
    benchkit::write_result("deferred_wasted_work.txt", &summary);
    benchkit::write_result("deferred_wasted_work.csv", &csv);
    Ok(())
}
