//! Deferred-vs-eager dispatch execution under heavy churn: how much real
//! accelerator work does deferral skip, and what does that buy in wall
//! time?
//!
//! TimelyFL's setting is a heavily-churned population (intermittently
//! available clients, §1); Papaya reports that at production scale the
//! dominant cost under churn is exactly the in-flight work a cancelled
//! dispatch wastes. `SimEngine::dispatch` now defers the PJRT executions
//! to the generation-validated finish event, so a churn-cancelled dispatch
//! costs nothing on the accelerator; `--eager-train` (here
//! `cfg.eager_train`) restores the historical train-at-dispatch behaviour
//! for the A/B.
//!
//! Every registered strategy runs the same churn-heavy Markov scenario in
//! both modes at a fixed seed. Per (strategy, mode) row: dispatches,
//! executions, avoided count + ratio, real PJRT train steps, and wall
//! seconds; per strategy a delta line with the avoided ratio and wall-time
//! saving. Round-stepped strategies are the control — they train
//! synchronously, so both modes must coincide (avoided = 0).

use anyhow::Result;
use timelyfl::benchkit::{self, Bench};
use timelyfl::coordinator::registry;
use timelyfl::experiment::{scenario, SweepGrid};
use timelyfl::metrics::report::Table;
use timelyfl::metrics::RunReport;

fn main() -> Result<()> {
    benchkit::banner(
        "deferred_wasted_work",
        "PJRT executions skipped by deferred dispatch under heavy churn (eager A/B)",
    );
    let bench = Bench::new()?;
    let rounds = bench.scale.rounds(40);

    let mut t = Table::new(&[
        "strategy",
        "mode",
        "dispatches",
        "executed",
        "avoided",
        "avoided_ratio",
        "pjrt_steps",
        "wall_secs",
    ]);
    let mut csv = String::from(
        "strategy,mode,dispatches,executed,avoided,avoided_ratio,pjrt_steps,wall_secs\n",
    );
    let mut deltas: Vec<String> = Vec::new();

    // The churn regime (~1/3 steady-state availability, dwells comparable
    // to round times — where SEAFL-style selective training lives) comes
    // from the `cifar_churn` scenario; the A/B is a strategy x eager_train
    // grid. Pinned serial: the headline numbers are wall-time deltas, so
    // cells must not co-run.
    let mut base = scenario::resolve("cifar_churn")?.config()?;
    base.rounds = rounds;
    base.eval_every = 20;
    let grid = SweepGrid::new(base)
        .strategy_axis_all()
        .axis("eager_train", &["true", "false"]);
    eprintln!("  {} cells (strategy x eager/deferred, rounds={rounds}) ...", grid.len());
    let result = bench.serial_runner().run(&grid)?;
    let mut cells = result.cells.into_iter();

    for info in registry::STRATEGIES {
        let mut by_mode: Vec<RunReport> = Vec::new();
        for eager in [true, false] {
            let mode = if eager { "eager" } else { "deferred" };
            let cell = cells.next().expect("grid covers strategy x mode");
            assert_eq!(cell.cell.cfg.eager_train, eager, "grid order drifted");
            let r = cell.reports.into_iter().next().unwrap();
            assert_eq!(r.strategy, info.name, "grid order drifted");
            t.row(vec![
                r.strategy.clone(),
                mode.to_string(),
                r.total_train_dispatches().to_string(),
                r.trainings_executed.to_string(),
                r.trainings_avoided.to_string(),
                format!("{:.3}", r.trainings_avoided_ratio()),
                r.real_train_steps.to_string(),
                format!("{:.2}", r.wall_secs),
            ]);
            csv.push_str(&format!(
                "{},{mode},{},{},{},{:.4},{},{:.3}\n",
                r.strategy,
                r.total_train_dispatches(),
                r.trainings_executed,
                r.trainings_avoided,
                r.trainings_avoided_ratio(),
                r.real_train_steps,
                r.wall_secs,
            ));
            by_mode.push(r);
        }
        let (eager, deferred) = (&by_mode[0], &by_mode[1]); // [true, false] order above
        let steps_saved = eager.real_train_steps.saturating_sub(deferred.real_train_steps);
        let wall_delta = eager.wall_secs - deferred.wall_secs;
        deltas.push(format!(
            "{}: avoided {}/{} dispatches ({:.1}%), {} fewer PJRT steps, wall {:+.2}s ({:+.1}%)",
            info.name,
            deferred.trainings_avoided,
            deferred.total_train_dispatches(),
            deferred.trainings_avoided_ratio() * 100.0,
            steps_saved,
            wall_delta,
            wall_delta / eager.wall_secs.max(1e-9) * 100.0,
        ));
    }

    let rendered = t.render();
    println!("{rendered}");
    println!("deferred-vs-eager deltas (same seed, same schedule):");
    for d in &deltas {
        println!("  {d}");
    }
    println!(
        "expected shape: event-driven strategies (FedBuff, SemiAsync) avoid a \
         non-trivial dispatch fraction and strictly reduce PJRT steps + wall time; \
         round-stepped strategies coincide across modes (the control)."
    );

    let mut summary = rendered;
    for d in &deltas {
        summary.push_str(d);
        summary.push('\n');
    }
    benchkit::write_result("deferred_wasted_work.txt", &summary);
    benchkit::write_result("deferred_wasted_work.csv", &csv);
    Ok(())
}
