//! Heterogeneity sweep — how each strategy degrades as the device fleet
//! gets more unequal (the scenario the paper's introduction motivates).
//!
//! Sweeps the compute-spread calibration (slowest/fastest ratio) from a
//! homogeneous fleet to 4x the paper's AI-Benchmark spread, and reports
//! each strategy's time to a fixed accuracy plus mean participation.
//! TimelyFL's gap should WIDEN with the spread: that is the
//! "heterogeneity-aware" claim in one table.
//!
//! ```bash
//! cargo run --release --example heterogeneity_sweep
//! ```

use anyhow::Result;
use timelyfl::benchkit::Bench;
use timelyfl::config::RunConfig;
use timelyfl::metrics::report::{fmt_hours, Table};

const TARGET: f64 = 0.35;

fn main() -> Result<()> {
    let bench = Bench::new()?;
    let mut t = Table::new(&[
        "compute spread",
        "strategy",
        "time to 35%",
        "mean particip",
        "final acc",
    ]);

    for spread in [1.5, 6.0, 13.3, 50.0] {
        for strat in ["TimelyFL", "FedBuff", "SyncFL"] {
            let mut cfg = RunConfig::preset("cifar_fedavg")?;
            cfg.strategy = strat.to_string();
            cfg.population = 48;
            cfg.concurrency = 24;
            cfg.rounds = bench.scale.rounds(240);
            cfg.eval_every = 10;
            cfg.fleet.compute_spread = spread;
            cfg.target_metric = Some(TARGET);
            eprintln!("spread={spread} {strat} ...");
            let r = bench.run(cfg)?;
            t.row(vec![
                format!("{spread}x"),
                strat.into(),
                fmt_hours(r.time_to_target(TARGET, true)),
                format!("{:.3}", r.mean_participation()),
                format!("{:.3}", r.best_metric(true).unwrap_or(0.0)),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "expected: all three tie on a homogeneous fleet; as the spread grows, SyncFL's\n\
         interval is hostage to the slowest device, FedBuff starves the slow half, and\n\
         TimelyFL holds participation (partial training) with the smallest slowdown."
    );
    Ok(())
}
