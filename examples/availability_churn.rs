//! Availability & churn demo — the client online/offline subsystem.
//!
//! ```bash
//! make artifacts                      # once: AOT-compile the model zoo
//! cargo run --release --example availability_churn
//! ```
//!
//! Part 1 needs no artifacts: it builds availability processes directly and
//! prints their on/off patterns. Part 2 runs TimelyFL end-to-end under
//! three availability regimes (always-on / Markov churn / diurnal) and
//! prints the participation table with drop attribution.

use anyhow::Result;
use timelyfl::availability::{
    AvailabilityConfig, AvailabilityKind, AvailabilityModel, SEED_SALT,
};
use timelyfl::config::RunConfig;
use timelyfl::coordinator::Simulation;
use timelyfl::metrics::report::participation_table;
use timelyfl::metrics::RunReport;

/// One character per hour: '#' online, '.' offline.
fn strip(model: &mut AvailabilityModel, client: usize, hours: usize) -> String {
    (0..hours)
        .map(|h| {
            // Sample mid-hour to show the dominant state of that hour.
            let t = (h as f64 + 0.5) * 3600.0;
            if model.is_available(client, t) {
                '#'
            } else {
                '.'
            }
        })
        .collect()
}

fn main() -> Result<()> {
    // --- Part 1: the processes themselves -------------------------------
    println!("diurnal availability, 24h period, 50% duty, 4 timezone shards");
    println!("(one char per hour over 48h; '#' online, '.' offline)\n");
    let diurnal = AvailabilityConfig {
        kind: AvailabilityKind::Diurnal,
        diurnal_period_secs: 86_400.0,
        diurnal_duty: 0.5,
        diurnal_shards: 4,
        ..AvailabilityConfig::default()
    };
    let mut model = AvailabilityModel::build(&diurnal, 4, 7 ^ SEED_SALT)?;
    for c in 0..4 {
        println!("  client {c} (shard {c}): {}", strip(&mut model, c, 48));
    }

    println!("\nmarkov churn, mean 2h online / 1h offline (log-normal dwells)\n");
    let markov = AvailabilityConfig {
        kind: AvailabilityKind::Markov,
        mean_online_secs: 2.0 * 3600.0,
        mean_offline_secs: 3600.0,
        dwell_sigma: 0.5,
        ..AvailabilityConfig::default()
    };
    let mut model = AvailabilityModel::build(&markov, 4, 7 ^ SEED_SALT)?;
    for c in 0..4 {
        let frac = model.online_fraction(c, 48.0 * 3600.0);
        println!(
            "  client {c}: {}  (online {:.0}%)",
            strip(&mut model, c, 48),
            frac * 100.0
        );
    }

    // --- Part 2: churn end-to-end through TimelyFL ----------------------
    println!("\nTimelyFL, 32 clients, 30 rounds, three availability regimes:\n");
    let mut reports: Vec<(&str, RunReport)> = Vec::new();
    for (label, availability) in [
        ("always-on", AvailabilityConfig::default()),
        (
            "markov 33% online",
            AvailabilityConfig {
                kind: AvailabilityKind::Markov,
                mean_online_secs: 600.0,
                mean_offline_secs: 1200.0,
                dwell_sigma: 0.5,
                ..AvailabilityConfig::default()
            },
        ),
        (
            "diurnal 50% duty",
            AvailabilityConfig {
                kind: AvailabilityKind::Diurnal,
                diurnal_period_secs: 7200.0,
                diurnal_duty: 0.5,
                diurnal_shards: 4,
                ..AvailabilityConfig::default()
            },
        ),
    ] {
        let mut cfg = RunConfig::preset("cifar_fedavg")?;
        cfg.population = 32;
        cfg.concurrency = 16;
        cfg.rounds = 30;
        cfg.eval_every = 10;
        cfg.availability = availability;
        eprintln!("running {label} ...");
        let sim = Simulation::new(cfg, "artifacts")?;
        reports.push((label, sim.run()?));
    }

    let rows: Vec<(&str, &RunReport)> = reports.iter().map(|(l, r)| (*l, r)).collect();
    println!("{}", participation_table(&rows).render());
    println!(
        "note how churn losses (avail_drops) are attributed separately from \
         deadline misses (deadline_drops), and participation tracks the \
         online fraction."
    );
    Ok(())
}
