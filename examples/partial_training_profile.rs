//! Partial-training profile — what a single slow client actually does.
//!
//! Walks one device through Algorithm 2 (local time update) and
//! Algorithm 3 (workload scheduling) for progressively tighter aggregation
//! intervals, then REALLY runs the scheduled partial workload through the
//! compiled PJRT executables, reporting the assigned (E, alpha), the
//! quantized compiled ratio, uploaded bytes, and the measured wall time.
//!
//! This is the paper §3.2.2 story in one binary: tighter interval -> lower
//! alpha -> fewer trainable suffix layers -> smaller upload, lower compute.
//!
//! ```bash
//! cargo run --release --example partial_training_profile
//! ```

use anyhow::Result;
use timelyfl::benchkit::Bench;
use timelyfl::config::RunConfig;
use timelyfl::coordinator::local_time::TimeEstimate;
use timelyfl::coordinator::scheduler::schedule;
use timelyfl::coordinator::trainer::train_client;
use timelyfl::metrics::report::Table;
use timelyfl::util::rng::Rng;

fn main() -> Result<()> {
    let bench = Bench::new()?;
    let mut cfg = RunConfig::preset("cifar_fedavg")?;
    cfg.population = 4;
    cfg.concurrency = 2;
    let sim = bench.simulation(cfg)?;
    let rt = &sim.runtime;
    let meta = &rt.meta;
    let global = rt.init_params(0)?;
    let mut rng = Rng::seed_from(42);

    // A slow device: 400s compute + 100s upload per unit epoch (estimated).
    let est = TimeEstimate {
        t_cmp: 400.0,
        t_com: 100.0,
    };
    println!(
        "device unit times: t_cmp={}s t_com={}s (total {}s) — model {} ({} params)\n",
        est.t_cmp,
        est.t_com,
        est.t_total(),
        meta.name,
        meta.total_params
    );

    let mut t = Table::new(&[
        "T_k (s)",
        "E",
        "alpha",
        "compiled ratio",
        "trainable tensors",
        "upload KB",
        "sched. time (s)",
        "measured wall (ms)",
        "mean loss",
    ]);

    for t_k in [1500.0, 1000.0, 500.0, 300.0, 150.0, 75.0] {
        let w = schedule(t_k, &est, cfg_max_epochs());
        let ratio = meta.quantize_ratio(w.alpha);
        // Scheduled (simulated) round time under the paper's linear model.
        let sched = if w.alpha < 1.0 {
            (est.t_cmp + est.t_com) * ratio.ratio
        } else {
            est.t_cmp * w.epochs as f64 + est.t_com
        };

        let t0 = std::time::Instant::now();
        let outcome = train_client(
            rt,
            &sim.dataset,
            0,
            &global,
            ratio,
            w.epochs,
            2, // steps per epoch
            0.05,
            &mut rng,
        )?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

        t.row(vec![
            format!("{t_k}"),
            w.epochs.to_string(),
            format!("{:.3}", w.alpha),
            format!("{}", ratio.ratio),
            format!("{}/{}", meta.params.len() - ratio.boundary, meta.params.len()),
            format!("{:.1}", outcome.update.bytes() as f64 / 1024.0),
            format!("{sched:.0}"),
            format!("{wall_ms:.1}"),
            format!("{:.3}", outcome.mean_loss),
        ]);
    }
    println!("{}", t.render());
    println!(
        "reading: once T_k < the device's unit total time (500s), alpha drops below 1 —\n\
         the client trains a shrinking output-side suffix and uploads proportionally\n\
         fewer bytes, but always lands inside the interval instead of going stale."
    );
    Ok(())
}

fn cfg_max_epochs() -> usize {
    4
}
