//! Quickstart — the smallest end-to-end TimelyFL run.
//!
//! ```bash
//! make artifacts                      # once: AOT-compile the model zoo
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a 32-client heterogeneous fleet, runs 40 communication rounds of
//! TimelyFL (Algorithm 1) on the synthetic CIFAR-10 stand-in, and prints
//! the learning curve plus participation statistics.

use anyhow::Result;
use timelyfl::config::RunConfig;
use timelyfl::coordinator::Simulation;
use timelyfl::simtime::hours;

fn main() -> Result<()> {
    // 1. Configure: start from the paper's CIFAR-10/FedAvg preset and
    //    shrink it to demo scale. Every field of RunConfig is plain data —
    //    see rust/src/config/mod.rs for the full surface.
    let mut cfg = RunConfig::preset("cifar_fedavg")?;
    cfg.population = 32;
    cfg.concurrency = 16;
    cfg.rounds = 40;
    cfg.eval_every = 5;

    // 2. Build: loads artifacts/manifest.json, compiles the AOT HLO
    //    executables on a PJRT CPU client, synthesises the non-iid
    //    federated dataset and the device fleet.
    let sim = Simulation::new(cfg, "artifacts")?;

    // 3. Run: the registry resolves the configured strategy (TimelyFL
    //    here) and the shared SimEngine drives the whole loop — probe,
    //    schedule, train (real PJRT executions), aggregate.
    let report = sim.run()?;

    // 4. Inspect.
    println!("round  sim_h   loss    accuracy");
    for p in &report.eval_points {
        println!(
            "{:>5}  {:>5.2}  {:.4}  {:.4}",
            p.round,
            hours(p.sim_secs),
            p.mean_loss,
            p.metric
        );
    }
    println!(
        "\n{} rounds in {:.2} simulated hours ({:.1}s wall, {} real train steps)",
        report.total_rounds,
        hours(report.sim_secs),
        report.wall_secs,
        report.real_train_steps
    );
    println!(
        "mean participation rate: {:.3} (TimelyFL's headline: slow devices keep contributing)",
        report.mean_participation()
    );
    Ok(())
}
