//! End-to-end driver — federated training of the largest zoo model
//! (`e2e_lm`: 6-layer causal transformer, d=256, vocab 4096, ~6.9M params)
//! for a few hundred aggregation rounds, proving all three layers compose:
//!
//!   Pallas kernel (L1, inside the lowered HLO) →
//!   JAX train-step graphs AOT'd per partial ratio (L2) →
//!   rust coordinator scheduling real PJRT executions (L3).
//!
//! Logs the loss/perplexity curve to stdout and results/e2e_loss_curve.csv.
//! Default budget (20 rounds, concurrency 6) fits a single-core CPU
//! testbed in a few minutes (~500 real PJRT train steps on the 6.9M-param
//! model); scale up with --rounds on bigger hardware. Flags:
//! --rounds N --strategy timelyfl|fedbuff|sync --out FILE.

use anyhow::Result;
use timelyfl::config::RunConfig;
use timelyfl::coordinator::registry;
use timelyfl::coordinator::Simulation;
use timelyfl::simtime::hours;

fn main() -> Result<()> {
    let mut rounds = 20usize;
    let mut strategy = String::from("TimelyFL");
    let mut out = String::from("results/e2e_loss_curve.csv");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--rounds" => rounds = args.next().expect("--rounds N").parse()?,
            "--strategy" => {
                strategy = registry::resolve(&args.next().expect("--strategy S"))?
                    .name
                    .to_string()
            }
            "--out" => out = args.next().expect("--out FILE"),
            other => anyhow::bail!("unknown flag {other:?}"),
        }
    }

    let mut cfg = RunConfig::default();
    cfg.model = "e2e_lm".into();
    cfg.strategy = strategy;
    cfg.population = 24;
    cfg.concurrency = 6;
    cfg.rounds = rounds;
    cfg.eval_every = 2;
    cfg.eval_batches = 1;
    cfg.client_lr = 0.25; // plain SGD on a small transformer needs a hot lr
    cfg.steps_per_epoch = 2;
    cfg.max_local_epochs = 2;
    cfg.sim_model_bytes = 27.4e6; // 6.86M params * 4B
    cfg.fleet.median_epoch_secs = 120.0;
    cfg.dirichlet_alpha = 0.3;

    eprintln!(
        "end-to-end: {} on e2e_lm ({} rounds, population {}, concurrency {})",
        cfg.strategy,
        cfg.rounds,
        cfg.population,
        cfg.concurrency
    );
    let sim = Simulation::new(cfg, "artifacts")?;
    eprintln!(
        "model: {} params across {} tensors; chunk={} fused steps/execution",
        sim.runtime.meta.total_params,
        sim.runtime.meta.params.len(),
        sim.runtime.meta.chunk
    );

    let report = sim.run()?;

    println!("round  sim_h    nll     ppl");
    for p in &report.eval_points {
        println!(
            "{:>5}  {:>6.2}  {:.4}  {:.2}",
            p.round,
            hours(p.sim_secs),
            p.mean_loss,
            p.metric
        );
    }
    let first = report.eval_points.first().expect("no evals");
    let last = report.eval_points.last().expect("no evals");
    println!(
        "\nppl {:.1} -> {:.1} over {} rounds ({:.2} sim hours, {:.0}s wall, {} train steps)",
        first.metric,
        last.metric,
        report.total_rounds,
        hours(report.sim_secs),
        report.wall_secs,
        report.real_train_steps
    );
    anyhow::ensure!(
        report.eval_points.len() < 2 || last.metric < first.metric,
        "perplexity did not improve — the stack is miswired"
    );

    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, report.curve_csv())?;
    eprintln!("wrote {out}");
    Ok(())
}
