#!/usr/bin/env bash
# Pre-merge gate: formatting, lints, and tests.
#
# The full test suite needs the AOT model artifacts (`make artifacts` /
# python/compile/aot.py) because the strategy integration tests execute
# real PJRT training. On a checkout without artifacts we still run every
# artifact-free suite (lib unit tests + pure-logic property tests) so the
# gate stays useful instead of failing on the missing-artifacts seed state.
set -euo pipefail
cd "$(dirname "$0")/.."

# Toolchain-free lint: dead code must be deleted, not silenced — the
# coordinator is the subsystem most prone to accumulating orphaned hooks
# during strategy refactors.
echo "== forbid #[allow(dead_code)] in rust/src/coordinator"
if grep -rn 'allow(dead_code)' rust/src/coordinator; then
    echo "check.sh: #[allow(dead_code)] is banned in coordinator/ — delete the dead code instead." >&2
    exit 1
fi

if ! command -v cargo >/dev/null 2>&1; then
    echo "check.sh: cargo not found on PATH — cannot run the gate." >&2
    echo "check.sh: install the rust toolchain (rustup) and re-run." >&2
    exit 1
fi

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

if [ -f artifacts/manifest.json ]; then
    echo "== cargo test (full suite, artifacts present)"
    cargo test -q
else
    echo "== artifacts/manifest.json missing: running artifact-free tests only" >&2
    echo "   (run 'make artifacts' to enable the PJRT integration suite)" >&2
    cargo test -q --lib
    cargo test -q --test coordinator_properties
    cargo test -q --test availability_properties
    cargo test -q --test correlated_churn_properties
    cargo test -q --test registry_properties
    cargo test -q --test wasted_work_properties
    cargo test -q --test experiment_properties
    cargo test -q --test fleet_properties
    cargo test -q --test parallel_agg_properties
    # These carry artifact-gated groups too, but those self-skip with a
    # message when artifacts/manifest.json is absent; the pure-logic
    # network properties, the config fuzz sweep, and the weigher algebra
    # always run.
    cargo test -q --test network_equivalence
    cargo test -q --test config_fuzz
    cargo test -q --test weigher_equivalence
fi

echo "check.sh: OK"
